//! k-bit quantized `W_up` proxy: the paper's out-of-range predictor
//! (§5.3), executed natively.
//!
//! The fold is valid per *neuron*: unit `j` may leave its calibrated
//! range `[lo_j, hi_j)` on a row whose other units are all fine. The 1-D
//! norm proxy ([`super::OutlierPredictor`]) cannot see that — it routes
//! whole rows by `‖x‖` and misses direction-dependent outliers. The
//! paper instead keeps a heavily quantized copy of the folded columns of
//! `W_up` and answers the in/out question per neuron:
//!
//! ```text
//! ẑ = x·Ŵ_up_F + b_up_F          (k-bit GEMM, ~bits/32 of the f32 cost
//!                                  in weight traffic)
//! flagged(i, j) = ẑ[i][j] ∉ [lo_j, hi_j)
//! ```
//!
//! Routing then composes with the existing per-row fallback machinery
//! ([`super::FoldedFfn`]): a row with no flagged neurons folds as-is; a
//! row with `1..=top_k` flagged neurons folds **plus top-K result
//! fixing** (only those neurons recompute their true pre-activation and
//! patch the folded output — two `d`-dots per fix); a row with more
//! flagged neurons than the fixing capacity falls back to the exact
//! dense path wholesale, so correctness degrades to the same bitwise
//! fallback the norm router uses.
//!
//! The quantized matrix lives in
//! [`QuantPanels`](super::kernels::QuantPanels): codes are `i8` in
//! [`NR`]-wide column panels (nibble-packed two per byte at
//! `bits <= 4`, streamed exactly like
//! [`PackedMatrix`](super::kernels::PackedMatrix) panels), with one f32
//! scale per (reduction-group, column) stored panel-major alongside.
//! The proxy GEMM runs through the **fused dequant kernels**
//! ([`matmul_q`](super::kernels::matmul_q)): codes are decoded and
//! scaled in registers inside the micro-kernel, so no widened f32 proxy
//! matrix is ever materialized. Quantization is symmetric per (group,
//! column) — the same scheme as `python/compile/tardis/predictor.py`,
//! so manifest-exported codes and scales load verbatim.

use super::dense::{DenseFfn, RangeTable};
use super::kernels::norm;
use super::kernels::pack::NR;
use super::kernels::{matmul_q_with, Epilogue, KernelDispatch, QuantPanels};
use crate::util::rng::Rng;
use crate::util::threadpool::ThreadPool;

/// Route of one batch row under the quantized per-neuron predictor.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum QuantRoute {
    /// No neuron flagged: the folded map alone.
    Folded,
    /// `1..=top_k` neurons flagged: folded map + per-neuron fixing.
    Fixed(usize),
    /// More than `top_k` neurons flagged: exact dense fallback.
    Fallback,
}

/// Cumulative counters of the quantized router.
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct QuantRouterStats {
    /// Rows with no flagged neuron (pure folded path).
    pub rows_clean: u64,
    /// Rows folded with per-neuron fixing.
    pub rows_fixed: u64,
    /// Rows routed to the dense fallback (flags exceeded `top_k`).
    pub rows_fallback: u64,
    /// Total (row, neuron) pairs the proxy flagged.
    pub neurons_flagged: u64,
    /// Fixes applied whose true pre-activation really was out of range.
    pub fixed_out_of_range: u64,
    /// Fixes applied that turned out in range (false flags; the fix is
    /// then an exact no-op).
    pub fixed_in_range: u64,
}

/// Routing quality of a predictor against ground-truth range
/// violations, over one evaluation workload. "Flagged" means the
/// (row, neuron) pair would execute on the dense path — via per-neuron
/// fixing or a whole-row fallback.
#[derive(Debug, Clone, Copy, Default, PartialEq)]
pub struct RoutingQuality {
    /// Of the flagged pairs, the fraction truly out of range.
    pub precision: f64,
    /// Of the truly out-of-range pairs, the fraction flagged.
    pub recall: f64,
    /// Fraction of all (row, neuron) pairs flagged.
    pub flag_rate: f64,
    /// Ground-truth out-of-range fraction of the workload.
    pub true_oor_rate: f64,
}

impl RoutingQuality {
    /// Build from raw counts; empty denominators follow the python
    /// evaluator (`max(count, 1)`), so a flag-free in-range workload
    /// scores 0/0 as zero rather than NaN.
    pub fn from_counts(tp: u64, flagged: u64, truly_oor: u64, total: u64) -> RoutingQuality {
        RoutingQuality {
            precision: tp as f64 / flagged.max(1) as f64,
            recall: tp as f64 / truly_oor.max(1) as f64,
            flag_rate: flagged as f64 / total.max(1) as f64,
            true_oor_rate: truly_oor as f64 / total.max(1) as f64,
        }
    }
}

/// A `[k, m]` weight matrix quantized to `bits` with one f32 scale per
/// (`group` reduction rows, column), stored as
/// [`QuantPanels`](super::kernels::QuantPanels) (layout diagram and
/// bit-packing rules in the `qgemm` module docs) and executed by the
/// fused dequant GEMM.
#[derive(Debug, Clone)]
pub struct QuantizedProxy {
    panels: QuantPanels,
}

impl QuantizedProxy {
    /// Symmetric per-(group, column) quantization of the first `m`
    /// columns of row-major `w[k, m_total]` (the folded prefix of
    /// `W_up`). `bits` must be in `2..=8`; the last group may be short
    /// when `group` does not divide `k`.
    pub fn quantize(
        w: &[f32],
        k: usize,
        m_total: usize,
        m: usize,
        bits: u8,
        group: usize,
    ) -> QuantizedProxy {
        assert!((2..=8).contains(&bits), "predictor bits {bits} not in 2..=8");
        assert!(group >= 1, "predictor group must be >= 1");
        assert!(m <= m_total && w.len() == k * m_total);
        let qmax = ((1i32 << (bits - 1)) - 1) as f32;
        let n_groups = k.div_ceil(group);
        let n_panels = m.div_ceil(NR);
        let mut codes = vec![0i8; n_panels * k * NR];
        let mut scales = vec![0f32; n_panels * n_groups * NR];
        for p in 0..n_panels {
            let col0 = p * NR;
            let ncols = (m - col0).min(NR);
            let cpanel = &mut codes[p * k * NR..(p + 1) * k * NR];
            let spanel = &mut scales[p * n_groups * NR..(p + 1) * n_groups * NR];
            for g in 0..n_groups {
                let k0 = g * group;
                let k1 = (k0 + group).min(k);
                for j in 0..ncols {
                    let col = col0 + j;
                    let mut absmax = 0f32;
                    for kk in k0..k1 {
                        absmax = absmax.max(w[kk * m_total + col].abs());
                    }
                    let scale = (absmax / qmax).max(1e-12);
                    spanel[g * NR + j] = scale;
                    for kk in k0..k1 {
                        let q = (w[kk * m_total + col] / scale)
                            .round_ties_even()
                            .clamp(-qmax, qmax);
                        cpanel[kk * NR + j] = q as i8;
                    }
                }
            }
        }
        QuantizedProxy { panels: QuantPanels::pack(codes, scales, k, m, group, bits) }
    }

    /// Pack pre-quantized codes and scales (e.g. from a manifest): codes
    /// row-major `[k, m_total]` i8, scales row-major
    /// `[ceil(k/group), m_total]` f32; the first `m` columns are kept.
    pub fn from_parts(
        codes: &[i8],
        scales: &[f32],
        k: usize,
        m_total: usize,
        m: usize,
        bits: u8,
        group: usize,
    ) -> QuantizedProxy {
        assert!((2..=8).contains(&bits), "predictor bits {bits} not in 2..=8");
        assert!(group >= 1 && m <= m_total);
        let n_groups = k.div_ceil(group);
        assert_eq!(codes.len(), k * m_total, "proxy codes shape mismatch");
        assert_eq!(scales.len(), n_groups * m_total, "proxy scales shape mismatch");
        // Imported codes must fit the declared width — catches a
        // `--pred-bits` override that disagrees with the bit width the
        // codes were actually exported at (which would otherwise only
        // skew the size accounting, silently).
        let qmax_i8 = ((1i32 << (bits - 1)) - 1) as i8;
        if let Some(&c) = codes.iter().find(|&&c| c < -qmax_i8 || c > qmax_i8) {
            panic!("proxy code {c} does not fit the declared {bits}-bit width");
        }
        let n_panels = m.div_ceil(NR);
        let mut pcodes = vec![0i8; n_panels * k * NR];
        let mut pscales = vec![0f32; n_panels * n_groups * NR];
        for p in 0..n_panels {
            let col0 = p * NR;
            let ncols = (m - col0).min(NR);
            let cpanel = &mut pcodes[p * k * NR..(p + 1) * k * NR];
            for kk in 0..k {
                for j in 0..ncols {
                    cpanel[kk * NR + j] = codes[kk * m_total + col0 + j];
                }
            }
            let spanel = &mut pscales[p * n_groups * NR..(p + 1) * n_groups * NR];
            for g in 0..n_groups {
                for j in 0..ncols {
                    spanel[g * NR + j] = scales[g * m_total + col0 + j];
                }
            }
        }
        QuantizedProxy { panels: QuantPanels::pack(pcodes, pscales, k, m, group, bits) }
    }

    pub fn k(&self) -> usize {
        self.panels.k()
    }

    pub fn m(&self) -> usize {
        self.panels.m()
    }

    pub fn bits(&self) -> u8 {
        self.panels.bits()
    }

    pub fn group(&self) -> usize {
        self.panels.group()
    }

    /// The packed code panels — the fused-GEMM operand, exposed so other
    /// consumers (e.g. a fully-quantized `W1` path) can run
    /// [`matmul_q`](super::kernels::matmul_q) against it directly.
    pub fn panels(&self) -> &QuantPanels {
        &self.panels
    }

    /// Approximate pre-activations `out[r][j] = Σ_kk x[r][kk] ·
    /// (codes[kk][j] · scales[kk/group][j]) + bias[j]` for `j < m`, via
    /// the fused dequant GEMM: codes are decoded and scaled in registers
    /// inside the micro-kernel (dequantize-in-register), never widened
    /// to an f32 matrix in memory. On the portable path the result is
    /// bitwise equal to `dequantize()` followed by the f32 `matmul`.
    pub fn forward_into(
        &self,
        pool: Option<&ThreadPool>,
        x: &[f32],
        rows: usize,
        bias: &[f32],
        out: &mut [f32],
    ) {
        self.forward_into_with(KernelDispatch::active(), pool, x, rows, bias, out);
    }

    /// [`Self::forward_into`] on an explicit dispatch path.
    pub fn forward_into_with(
        &self,
        disp: KernelDispatch,
        pool: Option<&ThreadPool>,
        x: &[f32],
        rows: usize,
        bias: &[f32],
        out: &mut [f32],
    ) {
        debug_assert!(bias.len() >= self.m());
        matmul_q_with(disp, pool, x, rows, &self.panels, Epilogue::Bias(bias), out);
    }

    /// Reconstructed row-major `[k, m]` f32 matrix (tests, error bounds).
    pub fn dequantize(&self) -> Vec<f32> {
        self.panels.dequantize()
    }

    /// Resident bytes of the packed representation (padding included;
    /// codes at `bits <= 4` occupy half a byte each).
    pub fn resident_bytes(&self) -> usize {
        self.panels.resident_bytes()
    }

    /// Deployed size in f32-parameter equivalents (`bits` per code plus
    /// one f16 scale per (group, column) — the python pipeline's §7.1
    /// accounting).
    pub fn size_params_f32(&self) -> f64 {
        let (k, m) = (self.k(), self.m());
        let n_groups = k.div_ceil(self.group());
        (k * m) as f64 * self.bits() as f64 / 32.0 + (n_groups * m) as f64 / 2.0
    }
}

/// Per-row router over a [`QuantizedProxy`]: flags neurons whose
/// approximate pre-activation leaves its calibrated range, and decides
/// fold / fold+fix / fallback under the `top_k` fixing capacity.
#[derive(Debug, Clone)]
pub struct QuantizedRouter {
    pub proxy: QuantizedProxy,
    pub top_k: usize,
    pub stats: QuantRouterStats,
}

impl QuantizedRouter {
    pub fn new(proxy: QuantizedProxy, top_k: usize) -> QuantizedRouter {
        QuantizedRouter { proxy, top_k, stats: QuantRouterStats::default() }
    }

    /// Route one row from its approximate pre-activations. Flagged
    /// neurons are appended to `fixes` as `(row, neuron)` pairs when the
    /// row stays folded; on fallback nothing is appended (the dense path
    /// recomputes every neuron exactly).
    pub fn decide_row(
        &mut self,
        z_hat: &[f32],
        table: &RangeTable,
        row: u32,
        fixes: &mut Vec<(u32, u32)>,
    ) -> QuantRoute {
        debug_assert_eq!(z_hat.len(), table.units());
        let mark = fixes.len();
        let mut flagged = 0usize;
        for (j, &z) in z_hat.iter().enumerate() {
            if !table.in_range(j, z) {
                flagged += 1;
                if flagged <= self.top_k {
                    fixes.push((row, j as u32));
                }
            }
        }
        self.stats.neurons_flagged += flagged as u64;
        if flagged == 0 {
            self.stats.rows_clean += 1;
            QuantRoute::Folded
        } else if flagged <= self.top_k {
            self.stats.rows_fixed += 1;
            QuantRoute::Fixed(flagged)
        } else {
            fixes.truncate(mark);
            self.stats.rows_fallback += 1;
            QuantRoute::Fallback
        }
    }

    /// Non-mutating variant of [`Self::decide_row`] used by the routing
    /// quality evaluator: returns the flagged neuron count (no fixes
    /// list, no stats).
    pub fn count_flags(&self, z_hat: &[f32], table: &RangeTable) -> usize {
        z_hat
            .iter()
            .enumerate()
            .filter(|&(j, &z)| !table.in_range(j, z))
            .count()
    }
}

/// Seeded evaluation workload with injected **direction-dependent
/// outliers** — the failure mode that separates the two predictors.
///
/// All rows share the same norm `norm_target`, so a per-row norm gate
/// whose learned radius covers `norm_target` routes every one of them to
/// the folded path. Most rows point in random directions (at a moderate
/// multiple of the provable radius their pre-activations stay in range
/// with overwhelming probability); every `outlier_every`-th row is
/// aligned with the most fragile folded `W_up` column (the smallest
/// `slack_j/‖col_j‖`, signed toward its tighter range edge), which
/// pushes exactly that neuron's pre-activation out of its calibrated
/// range. Only a direction-aware (per-neuron) predictor can tell the
/// two kinds of row apart.
///
/// Returns the `[rows, d_model]` batch; ground truth is computed
/// exactly by the evaluator, so occasional extra violations in the
/// random rows are harmless.
pub fn synthetic_outlier_workload(
    rng: &mut Rng,
    dense: &DenseFfn,
    table: &RangeTable,
    norm_target: f32,
    rows: usize,
    outlier_every: usize,
) -> Vec<f32> {
    let (d, h) = (dense.d_model, dense.d_ff);
    let nf = table.units();
    assert!(nf >= 1 && outlier_every >= 2);
    // Most fragile folded direction: argmin over (column, sign) of
    // slack/‖col‖.
    let mut best: Option<(usize, f32, f32)> = None; // (col, sign, ratio)
    for j in 0..nf {
        let col_norm = (0..d)
            .map(|l| {
                let w = dense.w_up[l * h + j] as f64;
                w * w
            })
            .sum::<f64>()
            .sqrt() as f32;
        if col_norm <= 1e-9 {
            continue;
        }
        let up = (table.hi[j] - dense.b_up[j]) / col_norm;
        let dn = (dense.b_up[j] - table.lo[j]) / col_norm;
        for (slack, sign) in [(up, 1.0f32), (dn, -1.0f32)] {
            let better = match best {
                None => slack > 0.0,
                Some((_, _, r)) => slack > 0.0 && slack < r,
            };
            if better {
                best = Some((j, sign, slack));
            }
        }
    }
    let (jstar, sign, _) = best.expect("no foldable direction");
    let mut dir: Vec<f32> = (0..d).map(|l| dense.w_up[l * h + jstar]).collect();
    let dlen = norm(&dir).max(1e-9);
    for v in dir.iter_mut() {
        *v *= sign / dlen;
    }

    let mut x = vec![0f32; rows * d];
    for (i, row) in x.chunks_mut(d).enumerate().take(rows) {
        if (i + 1) % outlier_every == 0 {
            for (v, &dv) in row.iter_mut().zip(&dir) {
                *v = norm_target * dv;
            }
        } else {
            for v in row.iter_mut() {
                *v = rng.normal() as f32;
            }
            let n = norm(row).max(1e-9);
            for v in row.iter_mut() {
                *v *= norm_target / n;
            }
        }
    }
    x
}

#[cfg(test)]
mod tests {
    use super::*;

    fn random_w(rng: &mut Rng, k: usize, m: usize) -> Vec<f32> {
        (0..k * m).map(|_| rng.normal() as f32 * 0.5).collect()
    }

    #[test]
    fn quantize_bounds_codes_and_error() {
        let mut rng = Rng::new(1);
        let (k, m) = (24, NR + 7); // two panels, short group tail (24 % 16 = 8)
        let w = random_w(&mut rng, k, m);
        for bits in [2u8, 4, 8] {
            let q = QuantizedProxy::quantize(&w, k, m, m, bits, 16);
            let qmax = (1i32 << (bits - 1)) - 1;
            let deq = q.dequantize();
            assert_eq!(deq.len(), k * m);
            // per-element error is bounded by half a quantization step
            // = scale/2 <= absmax/(2*qmax) <= max|w| / (2*qmax)
            let wmax = w.iter().fold(0f32, |a, &v| a.max(v.abs()));
            let bound = wmax / (2.0 * qmax as f32) + 1e-6;
            for (a, b) in w.iter().zip(&deq) {
                assert!((a - b).abs() <= bound, "bits={bits}: {a} vs {b}");
            }
            assert!(q.size_params_f32() < (k * m) as f64);
        }
        // more bits => strictly tighter reconstruction
        let e = |bits| {
            let q = QuantizedProxy::quantize(&w, k, m, m, bits, 16);
            q.dequantize()
                .iter()
                .zip(&w)
                .map(|(a, b)| ((a - b) * (a - b)) as f64)
                .sum::<f64>()
        };
        assert!(e(8) < e(4) && e(4) < e(2));
    }

    #[test]
    fn forward_matches_dequantized_matmul() {
        let mut rng = Rng::new(2);
        let (k, m, rows) = (20, NR + 3, 3);
        let w = random_w(&mut rng, k, m);
        let q = QuantizedProxy::quantize(&w, k, m, m, 4, 8);
        let x: Vec<f32> = (0..rows * k).map(|_| rng.normal() as f32).collect();
        let bias: Vec<f32> = (0..m).map(|_| rng.normal() as f32).collect();
        let mut got = vec![0f32; rows * m];
        q.forward_into(None, &x, rows, &bias, &mut got);
        // must match a plain matmul against the dequantized matrix
        // (regardless of dispatch path: the fused kernel's panel walk
        // and FMA contraction only reassociate/contract the sum)
        let deq = q.dequantize();
        for r in 0..rows {
            for j in 0..m {
                let want: f32 = (0..k)
                    .map(|kk| x[r * k + kk] * deq[kk * m + j])
                    .sum::<f32>()
                    + bias[j];
                let gval = got[r * m + j];
                assert!(
                    (gval - want).abs() <= 1e-3 * want.abs().max(1.0),
                    "r={r} j={j}: {gval} vs {want}"
                );
            }
        }
    }

    #[test]
    fn from_parts_roundtrips_packing() {
        let mut rng = Rng::new(3);
        let (k, m_total, m, group) = (16, NR + 5, NR + 2, 4);
        let w = random_w(&mut rng, k, m_total);
        let q = QuantizedProxy::quantize(&w, k, m_total, m_total, 4, group);
        // recover row-major codes/scales from the full quantization,
        // then re-pack only the first m columns via from_parts
        let n_groups = k.div_ceil(group);
        let deq = q.dequantize();
        let mut codes = vec![0i8; k * m_total];
        let mut scales = vec![0f32; n_groups * m_total];
        for p in 0..m_total.div_ceil(NR) {
            let col0 = p * NR;
            let ncols = (m_total - col0).min(NR);
            for kk in 0..k {
                for j in 0..ncols {
                    codes[kk * m_total + col0 + j] = q.panels().code_at(p, kk, j);
                }
            }
            for g in 0..n_groups {
                for j in 0..ncols {
                    scales[g * m_total + col0 + j] = q.panels().scale_at(p, g, j);
                }
            }
        }
        let q2 = QuantizedProxy::from_parts(&codes, &scales, k, m_total, m, 4, group);
        assert_eq!(q2.m(), m);
        let deq2 = q2.dequantize();
        for kk in 0..k {
            for j in 0..m {
                assert_eq!(deq2[kk * m + j], deq[kk * m_total + j], "({kk},{j})");
            }
        }
    }

    impl QuantizedProxy {
        /// Test helper: the same proxy with its codes widened to one
        /// `i8` each (the pre-packing layout), for layout-equivalence
        /// checks.
        fn unpacked_clone(&self) -> QuantizedProxy {
            QuantizedProxy { panels: self.panels.unpacked_clone() }
        }
    }

    #[test]
    fn bitpacked_codes_roundtrip_against_unpacked_layout() {
        // bits <= 4 stores two codes per byte; the packed store must be
        // observationally identical to the wide layout — same dequantized
        // matrix, bitwise the same proxy GEMM — at half the code bytes.
        let mut rng = Rng::new(7);
        for (k, m) in [(24, NR + 7), (5, 3), (16, 2 * NR), (9, NR - 1)] {
            let w = random_w(&mut rng, k, m);
            for bits in [2u8, 3, 4] {
                let q = QuantizedProxy::quantize(&w, k, m, m, bits, 4);
                assert!(q.panels().is_bitpacked());
                let wide = q.unpacked_clone();
                assert_eq!(q.dequantize(), wide.dequantize(), "bits={bits}");
                let rows = 3;
                let x: Vec<f32> = (0..rows * k).map(|_| rng.normal() as f32).collect();
                let bias: Vec<f32> = (0..m).map(|_| rng.normal() as f32).collect();
                let mut got = vec![0f32; rows * m];
                let mut want = vec![0f32; rows * m];
                q.forward_into(None, &x, rows, &bias, &mut got);
                wide.forward_into(None, &x, rows, &bias, &mut want);
                let got_bits: Vec<u32> = got.iter().map(|v| v.to_bits()).collect();
                let want_bits: Vec<u32> = want.iter().map(|v| v.to_bits()).collect();
                assert_eq!(got_bits, want_bits, "k={k} m={m} bits={bits}");
                // exactly half the code bytes (scales unchanged)
                let scale_bytes =
                    q.panels().n_panels() * q.panels().n_groups() * NR * 4;
                assert_eq!(
                    q.resident_bytes() - scale_bytes,
                    (wide.resident_bytes() - scale_bytes) / 2
                );
            }
            // wider codes stay one byte each
            let q8 = QuantizedProxy::quantize(&w, k, m, m, 8, 4);
            assert!(!q8.panels().is_bitpacked());
        }
    }

    #[test]
    fn router_routes_by_flag_count() {
        // 3 units, identity-ish proxy: z_hat passed directly.
        let table = RangeTable::from_calibration(
            &[-1.0, -1.0, -1.0],
            &[1.0, 1.0, 1.0],
            &[1.0; 3],
            &[0.0; 3],
        );
        let w = vec![0f32; 2 * 3];
        let proxy = QuantizedProxy::quantize(&w, 2, 3, 3, 4, 2);
        let mut router = QuantizedRouter::new(proxy, 1);
        let mut fixes = Vec::new();
        assert_eq!(
            router.decide_row(&[0.0, 0.5, -0.5], &table, 0, &mut fixes),
            QuantRoute::Folded
        );
        assert!(fixes.is_empty());
        assert_eq!(
            router.decide_row(&[2.0, 0.5, -0.5], &table, 1, &mut fixes),
            QuantRoute::Fixed(1)
        );
        assert_eq!(fixes, vec![(1, 0)]);
        // two flags exceed top_k=1: fallback, fixes list unchanged
        assert_eq!(
            router.decide_row(&[2.0, 0.5, 5.0], &table, 2, &mut fixes),
            QuantRoute::Fallback
        );
        assert_eq!(fixes, vec![(1, 0)]);
        assert_eq!(router.stats.rows_clean, 1);
        assert_eq!(router.stats.rows_fixed, 1);
        assert_eq!(router.stats.rows_fallback, 1);
        assert_eq!(router.stats.neurons_flagged, 3);
        assert_eq!(router.count_flags(&[2.0, 0.5, 5.0], &table), 2);
    }
}
