//! TARDIS: accelerating LLM inference via partially-linear feed-forward
//! networks (constant folding), reproduced as a three-layer rust + JAX +
//! Pallas stack. See DESIGN.md for the architecture and EXPERIMENTS.md
//! for the paper-vs-measured record.
//!
//! Layer map:
//! * [`runtime`]     — PJRT engine running the AOT artifacts (L2/L1
//!   output); behind the off-by-default `pjrt` cargo feature so the
//!   default build is std-only
//! * [`coordinator`] — the serving system. Each iteration a pluggable
//!   [`coordinator::scheduler::SchedulerPolicy`] turns a
//!   [`coordinator::scheduler::SchedView`] of the queue/slots/in-flight
//!   work into one composite [`coordinator::scheduler::StepPlan`]
//!   (admissions + concurrent prefill chunks + decode batch) that the
//!   engine executes and accounts — vLLM/Orca-style continuous batching
//!   with multiple prefills in flight
//! * [`costmodel`]   — analytic roofline reproduction of Fig 1b
//! * [`config`]      — manifest contract with the python compile path
//! * [`util`], [`bench`], [`testing`] — std-only substrates (no network)

pub mod bench;
pub mod config;
pub mod coordinator;
pub mod costmodel;
#[cfg(feature = "pjrt")]
pub mod runtime;
pub mod server;
pub mod testing;
pub mod util;
