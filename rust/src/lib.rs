//! TARDIS: accelerating LLM inference via partially-linear feed-forward
//! networks (constant folding), reproduced as a three-layer rust + JAX +
//! Pallas stack. See DESIGN.md for the architecture and EXPERIMENTS.md
//! for the paper-vs-measured record.
//!
//! Layer map:
//! * [`ffn`]         — native partially-linear FFN kernels: the
//!   `W' = W_down·A·W_up` constant fold, the dense reference path, and
//!   the online outlier predictor with per-row fallback batch-splitting
//! * [`runtime`]     — weight init/loading (std-only) plus the PJRT
//!   engine running the AOT artifacts behind the off-by-default `pjrt`
//!   cargo feature
//! * [`coordinator`] — the serving system over a paged KV cache. Each
//!   iteration a pluggable [`coordinator::scheduler::SchedulerPolicy`]
//!   turns a [`coordinator::scheduler::SchedView`] of the
//!   queue/slots/blocks/in-flight work into one composite
//!   [`coordinator::scheduler::StepPlan`] (preemptions + resumes +
//!   admissions + concurrent prefill chunks + decode batch, mixed in a
//!   single iteration under a token budget) that the engine executes
//!   and accounts — vLLM/Orca-style continuous batching with chunked
//!   prefill, block-table KV paging, and swap-based preemption. Step
//!   models span the backend matrix: `MockModel` (deterministic),
//!   `NativeModel` (tiny GELU transformer over [`ffn`], std-only,
//!   paged host cache) and `PjrtModel` (artifacts)
//! * [`costmodel`]   — analytic roofline reproduction of Fig 1b
//! * [`config`]      — manifest contract with the python compile path +
//!   the backend/variant configuration axis
//! * [`util`], [`bench`], [`testing`] — std-only substrates (no network)

pub mod bench;
pub mod config;
pub mod coordinator;
pub mod costmodel;
pub mod ffn;
pub mod runtime;
pub mod server;
pub mod testing;
pub mod util;
