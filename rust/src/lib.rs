//! TARDIS: accelerating LLM inference via partially-linear feed-forward
//! networks (constant folding), reproduced as a three-layer rust + JAX +
//! Pallas stack. See DESIGN.md for the architecture and EXPERIMENTS.md
//! for the paper-vs-measured record.
//!
//! Layer map:
//! * [`runtime`]     — PJRT engine running the AOT artifacts (L2/L1 output)
//! * [`coordinator`] — the serving system (router, batcher, scheduler, KV)
//! * [`costmodel`]   — analytic roofline reproduction of Fig 1b
//! * [`config`]      — manifest contract with the python compile path
//! * [`util`], [`bench`], [`testing`] — std-only substrates (no network)

pub mod bench;
pub mod config;
pub mod coordinator;
pub mod costmodel;
pub mod runtime;
pub mod server;
pub mod testing;
pub mod util;
