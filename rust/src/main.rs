//! `tardis` CLI — the L3 entrypoint.
//!
//! The backend is a first-class axis (`--backend native|mock|pjrt`):
//!   native — pure-Rust tiny GELU transformer (TINY_GELU shape) with
//!            dense or TARDIS partially-linear FFNs; std-only, no
//!            artifacts (the default)
//!   mock   — deterministic mock replicas (scheduler/protocol work)
//!   pjrt   — AOT artifacts through the PJRT runtime (needs a build
//!            with --features pjrt)
//!
//! Subcommands:
//!   costmodel    — print the Fig 1b analytic breakdown (paper-scale)
//!   generate     — run one prompt through a variant, print text + stats
//!   serve        — TCP server (line-delimited JSON) over replicas
//!   serve-mock   — alias for `serve --backend mock`
//!   variants     — list variants: native measured decode latency next
//!                  to the costmodel's theoretical tardis speedups (plus
//!                  the artifact manifest under --features pjrt)
//!   bench-decode — decode-step timing, dense vs tardis fold ratios
//!   bench-trace  — trace-driven workload replay on the deterministic
//!                  virtual clock: per-tier SLO goodput by policy, with
//!                  the edf-vs-fifo goodput regression gate

use anyhow::{anyhow, Result};

use tardis::config::{
    native_ffn_mode, BackendKind, FfnMode, Manifest, NativeModelConfig,
    PredictorKind, TardisFfnConfig,
};
use tardis::coordinator::engine_loop::{EngineConfig, InferenceEngine};
use tardis::coordinator::health::FaultPlan;
use tardis::coordinator::model::{MockModel, NativeModel, StepModel};
use tardis::coordinator::queue::OverloadPolicy;
use tardis::coordinator::request::SamplingParams;
use tardis::coordinator::router::{FrontDoor, FrontDoorConfig, ReplicaFactory, Router};
use tardis::coordinator::scheduler::PolicyKind;
use tardis::costmodel;
use tardis::ffn::RoutingQuality;
use tardis::runtime::weights::NativeWeights;
use tardis::server::protocol::{decode_tokens, encode_text};
use tardis::testing::trace;
use tardis::util::cli::Args;
use tardis::util::stats::Samples;

#[cfg(feature = "pjrt")]
use tardis::coordinator::model::PjrtModel;
#[cfg(feature = "pjrt")]
use tardis::runtime::Engine;

fn usage() -> ! {
    eprintln!(
        "usage: tardis <costmodel|generate|serve|serve-mock|variants|bench-decode|bench-trace> [flags]
  common flags:
    --backend KIND         native|mock|pjrt (default native; pjrt needs
                           a build with --features pjrt)
    --artifacts DIR        artifacts directory (default: artifacts or
                           $TARDIS_ARTIFACTS). pjrt: HLO executables.
                           native generate/serve: load weights and
                           per-neuron calibrated ranges from the
                           manifest instead of seeded synthesis
    --variant NAME         model variant (default: tardis80; native
                           accepts dense|tardis<PCT>|tardis-ref<PCT>,
                           or any manifest variant with --artifacts)
  native backend flags:
    --slots N              KV slots / decode batch (default 4)
    --max-seq N            context length (default 256)
    --threads N            matmul worker threads (default 0 = serial)
    --block-size N         tokens per paged-KV block (default 16)
    --kv-blocks N          KV blocks in the pool (default 0 = enough for
                           every slot to span the full context; smaller
                           pools oversubscribe the cache and trigger
                           preemption/swap under load)
    --predictor KIND       outlier predictor: norm|quantized (default:
                           norm, or the manifest's choice)
    --pred-bits N          quantized-proxy bit width (2..=8, default 4)
    --fix-k N              top-K result-fixing capacity per row
                           (default 8); rows with more predicted
                           out-of-range neurons fall back densely
  scheduling flags (serve / serve-mock / generate):
    --policy NAME          admission policy: fifo|spf|priority|edf (default fifo)
    --max-prefills N       concurrent prefill jobs (default 2)
    --chunk-budget N       prefill chunks per iteration (default 2)
    --max-step-tokens N    token budget of one mixed iteration (decode
                           rows + prefill chunk lengths; default 0 =
                           unbounded)
    --segregated           disable mixed prefill+decode iterations (the
                           pre-paged alternating planner, for baselines)
    --no-prefix-cache      disable cross-request KV prefix sharing (the
                           radix cache + copy-on-write; on by default on
                           backends that support block sharing)
    --speculate-k N        self-speculative decode: draft N tokens per
                           step through the all-folded no-fallback FFN
                           path, verify them in one batched forward and
                           retire the agreeing prefix (greedy requests
                           only, streams stay bitwise identical;
                           default 0 = off)
    --speculate-adaptive   shrink a slot's draft window while its
                           acceptance is poor and regrow it on full
                           windows; degraded-tier requests may draft up
                           to 2k
    --queue-capacity N     admission queue depth before backpressure (default 64)
  generate:
    --prompt TEXT          prompt (default: \"the quick \")
    --max-tokens N         tokens to generate (default 48)
    --temperature T        sampling temperature (default 0 = greedy)
    --priority N           admission priority (default 0)
    --ttft-deadline-ms N   TTFT SLO (default: none); under --policy edf,
                           tighter deadlines admit sooner
    --tpot-deadline-ms N   per-token decode-gap SLO (default: none)
  serve / serve-mock:
    --addr HOST:PORT       listen address (default 127.0.0.1:7437)
    --variants A,B         variants to load (default dense,tardis80;
                           serve-mock default mock)
    --replicas N           engine replicas per variant (default 1; mock
                           and native backends run each replica on its
                           own worker thread behind the fault-tolerant
                           front door; pjrt stays single-threaded)
    --journal PATH         append-only admission journal (JSONL); on
                           restart, admitted-but-uncompleted requests
                           replay onto live replicas
    --queue-cap N          per-replica in-flight cap before the front
                           door sheds with {{\"err\":\"overloaded\"}}
                           (default 64)
    --degrade-at X         queue pressure in [0,1] where the overload
                           ladder force-folds the lowest tiers
                           (default: disabled)
    --shed-at X            queue pressure where the ladder sheds the
                           lowest tiers outright (default: disabled)
    --tier-max N           highest --priority the ladder may degrade
                           or shed (default 0)
    --max-requests N       exit after N served requests (for scripted runs)
    TARDIS_FAULT_PLAN      deterministic fault injection, e.g.
                           \"kill:1@12,fail:0@9,dropconn@3,journal@5\"
                           (see docs/serving.md)
  bench-trace:
    --trace PATH           replay a JSONL trace fixture instead of
                           generating one (see docs/serving.md for the
                           schema)
    --preset NAME          generated workload: overload|default
                           (default overload — the committed-fixture
                           shape: bulk tier vs tight-deadline tier)
    --sessions N           sessions to generate (preset default)
    --seed N               trace + sampler seed (preset default)
    --trace-out PATH       dump the materialized trace as a JSONL
                           fixture before replaying
    --policies A,B         policies to replay (default fifo,edf)
    --step-cost-us N       virtual microseconds per engine iteration
                           (default 1000)
    --degrade-at X         queue pressure where the lowest tier is
                           force-folded (default 0.5; >1 disables)
    --shed-at X            queue pressure where the lowest tier sheds
                           (default 0.9; >1 disables)
    --tier-max N           highest priority the ladder may touch
                           (default 0)
    --assert-goodput       (or TARDIS_ASSERT_GOODPUT=1) exit non-zero
                           unless edf goodput strictly exceeds fifo's,
                           with one re-measure on failure
    results merge into BENCH_native_ffn.json under coordinator.slo
    (sibling keys preserved; override path with TARDIS_BENCH_JSON)
  variants / bench-decode:
    --steps N              decode steps to time (default 64)
    --warmup N             untimed predictor-warmup steps (default 8)
    --speculate-k N        bench-decode: also measure single-stream
                           speculative decode (forced-fold drafts, N per
                           step) against plain decode per variant,
                           reporting acceptance rate and tokens/s, and
                           merge them under decode.speculative in the
                           bench JSON
    --assert-spec-speedup  (or TARDIS_ASSERT_SPEC_SPEEDUP=1) exit
                           non-zero unless the best speculative variant's
                           tokens/s strictly beats its plain decode, with
                           one re-measure on failure
    --assert-speedup R     exit non-zero unless a tardis variant reaches
                           a measured speedup of at least R vs dense
    --assert-gflops G      exit non-zero unless the packed single-thread
                           GEMM kernel reaches G GFLOP/s; also requires
                           the SIMD path (when active) to beat portable
                           and the fused 4-bit proxy GEMM to move >= 2x
                           fewer bytes/token than a widened f32 matrix
  both also print routing precision/recall of the norm and quantized
  predictors against ground-truth range violations on a seeded
  direction-dependent-outlier workload; bench-decode reports the active
  kernel ISA (portable or avx2+fma; pin with TARDIS_FORCE_SCALAR=1),
  GFLOP/s on both dispatch paths and bytes-moved/token with effective
  GB/s at rows=1, and merges everything into BENCH_native_ffn.json
  (machine-readable per-PR perf record, sibling suites' keys preserved;
  override the path with TARDIS_BENCH_JSON)"
    );
    std::process::exit(2);
}

/// Shared scheduler/engine config from the CLI flags.
fn engine_config(args: &Args) -> Result<EngineConfig> {
    let mut cfg = EngineConfig::default();
    if let Some(p) = args.opt_str("policy") {
        cfg.scheduler.policy = PolicyKind::parse(&p).ok_or_else(|| {
            anyhow!("unknown policy {p:?} (expected fifo|spf|priority|edf)")
        })?;
    }
    cfg.scheduler.max_concurrent_prefills =
        args.usize("max-prefills", cfg.scheduler.max_concurrent_prefills)?;
    cfg.scheduler.chunk_budget =
        args.usize("chunk-budget", cfg.scheduler.chunk_budget)?;
    cfg.scheduler.max_step_tokens =
        args.usize("max-step-tokens", cfg.scheduler.max_step_tokens)?;
    if args.bool("segregated") {
        cfg.scheduler.mixed = false;
    }
    if args.bool("no-prefix-cache") {
        cfg.prefix_cache = false;
    }
    cfg.speculate_k = args.usize("speculate-k", cfg.speculate_k)?;
    if args.bool("speculate-adaptive") {
        cfg.speculate_adaptive = true;
    }
    cfg.queue_capacity = args.usize("queue-capacity", cfg.queue_capacity)?;
    Ok(cfg)
}

fn backend(args: &Args) -> Result<BackendKind> {
    match args.opt_str("backend") {
        None => Ok(BackendKind::default()),
        Some(s) => BackendKind::parse(&s)
            .ok_or_else(|| anyhow!("unknown backend {s:?} (native|mock|pjrt)")),
    }
}

/// Native model shape from the CLI flags (TINY_GELU defaults).
fn native_model_cfg(args: &Args) -> Result<NativeModelConfig> {
    let mut cfg = NativeModelConfig::tiny_gelu();
    cfg.batch = args.usize("slots", cfg.batch)?;
    cfg.max_seq = args.usize("max-seq", cfg.max_seq)?;
    cfg.threads = args.usize("threads", cfg.threads)?;
    cfg.kv_block_size = args.usize("block-size", cfg.kv_block_size)?;
    cfg.kv_blocks = args.usize("kv-blocks", cfg.kv_blocks)?;
    Ok(cfg)
}

fn native_mode(variant: &str) -> Result<FfnMode> {
    native_ffn_mode(variant).ok_or_else(|| {
        anyhow!(
            "unknown native variant {variant:?} \
             (expected dense, tardis<PCT> or tardis-ref<PCT>)"
        )
    })
}

/// CLI overrides for the TARDIS predictor knobs.
fn tardis_overrides(args: &Args, t: TardisFfnConfig) -> Result<TardisFfnConfig> {
    let mut t = t;
    if let Some(s) = args.opt_str("predictor") {
        t.predictor = PredictorKind::parse(&s)
            .ok_or_else(|| anyhow!("unknown predictor {s:?} (norm|quantized)"))?;
    }
    let bits = args.usize("pred-bits", t.predictor_bits as usize)?;
    anyhow::ensure!(
        (2..=8).contains(&bits),
        "--pred-bits expects a width in 2..=8, got {bits}"
    );
    t.predictor_bits = bits as u8;
    t.top_k = args.usize("fix-k", t.top_k)?;
    Ok(t)
}

fn mode_with_overrides(args: &Args, mode: FfnMode) -> Result<FfnMode> {
    Ok(match mode {
        FfnMode::Dense => FfnMode::Dense,
        FfnMode::Tardis(t) => FfnMode::Tardis(tardis_overrides(args, t)?),
        FfnMode::TardisReference(t) => {
            FfnMode::TardisReference(tardis_overrides(args, t)?)
        }
    })
}

fn manifest_path(args: &Args) -> std::path::PathBuf {
    args.opt_str("artifacts")
        .map(|d| std::path::PathBuf::from(d).join("manifest.json"))
        .unwrap_or_else(Manifest::default_path)
}

/// Build a native model from a manifest directory: the shape comes from
/// the manifest's model block, the weights (and, when exported, the
/// per-neuron calibrated ranges + quantized predictor) from the
/// variant's blob — nothing is synthesized.
fn native_model_from_artifacts(
    args: &Args,
    variant: &str,
) -> Result<(NativeModel, String)> {
    let path = manifest_path(args);
    let manifest = Manifest::load(&path)?;
    let spec = manifest.variant(variant)?;
    let cfg = NativeModelConfig {
        vocab: manifest.model.vocab,
        d_model: manifest.model.d_model,
        n_layers: manifest.model.n_layers,
        n_heads: manifest.model.n_heads,
        d_ff: manifest.model.d_ff,
        max_seq: args.usize("max-seq", manifest.model.max_seq)?,
        batch: args.usize("slots", manifest.batch)?,
        prefill_buckets: manifest.prefill_buckets.clone(),
        seed: 0,
        threads: args.usize("threads", 0)?,
        kv_block_size: args.usize("block-size", 16)?,
        kv_blocks: args.usize("kv-blocks", 0)?,
    };
    let mode = match spec.tardis {
        Some(t) => FfnMode::Tardis(tardis_overrides(args, t)?),
        None => FfnMode::Dense,
    };
    let weights = NativeWeights::load(&manifest.dir, spec, &cfg)?;
    let calibrated = weights.layers.iter().filter(|l| l.calib.is_some()).count();
    let label = format!(
        "manifest {} ({} of {} layers per-neuron calibrated)",
        path.display(),
        calibrated,
        cfg.n_layers
    );
    Ok((NativeModel::with_weights(cfg, weights, &mode), label))
}

fn sampling_params(args: &Args) -> Result<SamplingParams> {
    Ok(SamplingParams {
        temperature: args.f64("temperature", 0.0)? as f32,
        top_k: args.usize("top-k", 0)?,
        max_tokens: args.usize("max-tokens", 48)?,
        stop_token: None,
        seed: args.usize("seed", 0)? as u64,
        priority: match args.opt_str("priority") {
            None => 0,
            Some(s) => s.parse::<i32>().map_err(|_| {
                anyhow!("--priority expects an integer, got {s:?}")
            })?,
        },
        ttft_deadline_ms: parse_deadline(args, "ttft-deadline-ms")?,
        tpot_deadline_ms: parse_deadline(args, "tpot-deadline-ms")?,
        degrade: false,
    })
}

fn parse_deadline(args: &Args, key: &str) -> Result<Option<u64>> {
    args.opt_str(key)
        .map(|s| {
            s.parse::<u64>()
                .map_err(|_| anyhow!("--{key} expects a non-negative integer, got {s:?}"))
        })
        .transpose()
}

fn parse_max_requests(args: &Args) -> Result<Option<usize>> {
    args.opt_str("max-requests")
        .map(|s| s.parse::<usize>())
        .transpose()
        .map_err(|_| anyhow!("--max-requests expects an integer"))
}

/// Serve through the synchronous [`Router`]: one shared thread steps
/// every replica. Required for backends that are not `Send` (pjrt).
fn run_server<M: StepModel>(
    replicas: Vec<(String, InferenceEngine<M>)>,
    args: &Args,
    label: &str,
) -> Result<()> {
    let router = Router::new(replicas);
    let addr = args.str("addr", "127.0.0.1:7437");
    let max_requests = parse_max_requests(args)?;
    let served = tardis::server::tcp::serve(router, &addr, max_requests)?;
    eprintln!("[{label}] done, served {served} requests");
    Ok(())
}

/// Front-door knobs from the CLI flags plus the `TARDIS_FAULT_PLAN` env.
fn front_door_config(args: &Args) -> Result<FrontDoorConfig> {
    let base = FrontDoorConfig::default();
    Ok(FrontDoorConfig {
        queue_cap: args.usize("queue-cap", base.queue_cap)?,
        journal: args.opt_str("journal").map(std::path::PathBuf::from),
        fault_plan: FaultPlan::from_env()?,
        overload: OverloadPolicy {
            degrade_at: args.f64("degrade-at", base.overload.degrade_at)?,
            shed_at: args.f64("shed-at", base.overload.shed_at)?,
            tier_max: args.usize("tier-max", base.overload.tier_max as usize)? as i32,
        },
        ..base
    })
}

/// Serve through the fault-tolerant [`FrontDoor`]: each replica steps on
/// its own worker thread; panics and step errors quarantine the replica
/// and replay its journaled in-flight work onto survivors.
fn run_front_door<M: StepModel + Send + 'static>(
    replicas: Vec<(String, ReplicaFactory<M>)>,
    args: &Args,
    label: &str,
) -> Result<()> {
    let fd = front_door_config(args)?;
    let front = FrontDoor::new(replicas, fd)?;
    let addr = args.str("addr", "127.0.0.1:7437");
    let max_requests = parse_max_requests(args)?;
    let served = tardis::server::tcp::serve(front, &addr, max_requests)?;
    eprintln!("[{label}] done, served {served} requests");
    Ok(())
}

// ---------------------------------------------------------------------------
// costmodel
// ---------------------------------------------------------------------------

fn cmd_costmodel(_args: &Args) -> Result<()> {
    let b =
        costmodel::inference_breakdown(&costmodel::FALCON_7B, &costmodel::RTX_4090, 1, 91, 178);
    println!("Fig 1b reproduction — Falcon-7B on RTX 4090, 91 prompt + 178 generated tokens");
    println!("  component      share of inference time");
    println!("  MHA I/O        {:5.1}%", b.attn_io * 100.0);
    println!("  MHA compute    {:5.1}%", b.attn_compute * 100.0);
    println!("  FFN I/O        {:5.1}%   (paper: 78.2%)", b.ffn_io * 100.0);
    println!("  FFN compute    {:5.1}%", b.ffn_compute * 100.0);
    println!("  modeled total  {:.2}s", b.total_s);
    println!();
    println!("TARDIS theoretical speedups (decode, ctx 128):");
    for ratio in [0.3, 0.5, 0.7, 0.8] {
        let (ffn, e2e) = costmodel::tardis_speedup(
            &costmodel::FALCON_7B,
            &costmodel::RTX_4090,
            1,
            128,
            ratio,
            0.05,
        );
        println!("  ratio {:.0}%: FFN {:.2}x, end-to-end {:.2}x", ratio * 100.0, ffn, e2e);
    }
    Ok(())
}

// ---------------------------------------------------------------------------
// serve (all backends) + serve-mock alias
// ---------------------------------------------------------------------------

fn cmd_serve(args: &Args, forced: Option<BackendKind>) -> Result<()> {
    let kind = match forced {
        Some(k) => k,
        None => backend(args)?,
    };
    let cfg = engine_config(args)?;
    match kind {
        BackendKind::Mock => {
            let slots = args.usize("slots", 4)?;
            let max_seq = args.usize("max-seq", 256)?;
            let copies = args.usize("replicas", 1)?.max(1);
            let names = args.list("variants", &["mock"]);
            let mut replicas: Vec<(String, ReplicaFactory<MockModel>)> = Vec::new();
            for name in &names {
                for _ in 0..copies {
                    let cfg = cfg.clone();
                    replicas.push((
                        name.clone(),
                        Box::new(move || {
                            Ok(InferenceEngine::new(
                                MockModel::new(slots, max_seq, 256, vec![16, 64]),
                                cfg.clone(),
                            ))
                        }),
                    ));
                }
            }
            eprintln!(
                "[serve] backend=mock policy={} prefix_cache={} \
                 variants={names:?} replicas_per_variant={copies}",
                cfg.scheduler.policy.name(),
                cfg.prefix_cache
            );
            run_front_door(replicas, args, "serve")
        }
        BackendKind::Native => {
            let from_manifest = args.opt_str("artifacts").is_some();
            let model_cfg = native_model_cfg(args)?;
            let copies = args.usize("replicas", 1)?.max(1);
            let names = args.list("variants", &["dense", "tardis80"]);
            let mut replicas: Vec<(String, ReplicaFactory<NativeModel>)> = Vec::new();
            for name in &names {
                // Fail fast on bad variants/manifests before the front
                // door treats construction errors as replica faults.
                if from_manifest {
                    let (_, label) = native_model_from_artifacts(args, name)?;
                    eprintln!("[serve] loading {name} from {label}");
                } else {
                    mode_with_overrides(args, native_mode(name)?)?;
                }
                for _ in 0..copies {
                    let args = args.clone();
                    let name_in = name.clone();
                    let cfg = cfg.clone();
                    let model_cfg = model_cfg.clone();
                    replicas.push((
                        name.clone(),
                        Box::new(move || {
                            let model = if from_manifest {
                                native_model_from_artifacts(&args, &name_in)?.0
                            } else {
                                let mode =
                                    mode_with_overrides(&args, native_mode(&name_in)?)?;
                                NativeModel::new(model_cfg.clone(), &mode)
                            };
                            Ok(InferenceEngine::new(model, cfg.clone()))
                        }),
                    ));
                }
            }
            eprintln!(
                "[serve] backend=native policy={} prefix_cache={} \
                 variants={names:?} replicas_per_variant={copies}",
                cfg.scheduler.policy.name(),
                cfg.prefix_cache
            );
            run_front_door(replicas, args, "serve")
        }
        BackendKind::Pjrt => cmd_serve_pjrt(args, cfg),
    }
}

#[cfg(feature = "pjrt")]
fn cmd_serve_pjrt(args: &Args, cfg: EngineConfig) -> Result<()> {
    let manifest = Manifest::load(&manifest_path(args))?;
    let engine = Engine::cpu()?;
    let variants = args.list("variants", &["dense", "tardis80"]);
    let mut replicas = Vec::new();
    for v in &variants {
        eprintln!("[serve] loading {v} ...");
        replicas.push((
            v.clone(),
            load_engine(&engine, &manifest, v, Some(&main_exec_tags(&manifest)),
                        cfg.clone())?,
        ));
    }
    run_server(replicas, args, "serve")
}

#[cfg(not(feature = "pjrt"))]
fn cmd_serve_pjrt(_args: &Args, _cfg: EngineConfig) -> Result<()> {
    Err(pjrt_unavailable("serve"))
}

#[cfg(not(feature = "pjrt"))]
fn pjrt_unavailable(cmd: &str) -> anyhow::Error {
    anyhow!(
        "backend pjrt for {cmd:?} needs the PJRT runtime; rebuild with \
         `cargo build --features pjrt` (and real xla bindings)"
    )
}

// ---------------------------------------------------------------------------
// generate
// ---------------------------------------------------------------------------

fn cmd_generate(args: &Args) -> Result<()> {
    match backend(args)? {
        BackendKind::Native => cmd_generate_native(args),
        BackendKind::Mock => Err(anyhow!(
            "generate on the mock backend produces meaningless tokens; \
             use --backend native"
        )),
        BackendKind::Pjrt => cmd_generate_pjrt(args),
    }
}

fn cmd_generate_native(args: &Args) -> Result<()> {
    let variant = args.str("variant", "tardis80");
    let model = if args.opt_str("artifacts").is_some() {
        let (model, label) = native_model_from_artifacts(args, &variant)?;
        eprintln!("[generate] backend=native variant={variant} ({label})");
        model
    } else {
        let mode = mode_with_overrides(args, native_mode(&variant)?)?;
        eprintln!("[generate] backend=native variant={variant} (seeded weights)");
        NativeModel::new(native_model_cfg(args)?, &mode)
    };
    let mut ie = InferenceEngine::new(model, engine_config(args)?);
    let prompt = args.str("prompt", "the quick ");
    let params = sampling_params(args)?;
    let t0 = std::time::Instant::now();
    let c = ie.generate_sequential(encode_text(&prompt), params)?;
    let dt = t0.elapsed().as_secs_f64();
    println!("{}{}", prompt, decode_tokens(&c.tokens));
    let ratio = ie
        .model
        .fold_compression_ratio()
        .map(|r| format!("{:.1}%", r * 100.0))
        .unwrap_or_else(|| "-".to_string());
    let fallback = ie
        .stats
        .ffn_fallback_rate()
        .map(|r| format!("{:.1}%", r * 100.0))
        .unwrap_or_else(|| "-".to_string());
    eprintln!(
        "[generate] {} tokens in {:.2}s ({:.1} tok/s, decode mean {:.2} ms, \
         fold compression {ratio}, fallback rate {fallback})",
        c.tokens.len(),
        dt,
        c.tokens.len() as f64 / dt,
        ie.decode_latency_ms.mean(),
    );
    Ok(())
}

#[cfg(feature = "pjrt")]
fn cmd_generate_pjrt(args: &Args) -> Result<()> {
    let manifest = Manifest::load(&manifest_path(args))?;
    let variant = args.str("variant", "tardis80");
    let engine = Engine::cpu()?;
    eprintln!("[generate] platform={} variant={variant}", engine.platform());
    let mut ie = load_engine(
        &engine,
        &manifest,
        &variant,
        Some(&main_exec_tags(&manifest)),
        engine_config(args)?,
    )?;
    let prompt = args.str("prompt", "the quick ");
    let params = sampling_params(args)?;
    let t0 = std::time::Instant::now();
    let c = ie.generate_sequential(encode_text(&prompt), params)?;
    let dt = t0.elapsed().as_secs_f64();
    println!("{}{}", prompt, decode_tokens(&c.tokens));
    eprintln!(
        "[generate] {} tokens in {:.2}s ({:.1} tok/s, decode mean {:.2} ms, \
         compression ratio {:.1}%)",
        c.tokens.len(),
        dt,
        c.tokens.len() as f64 / dt,
        ie.decode_latency_ms.mean(),
        ie.model.compression_ratio() * 100.0
    );
    Ok(())
}

#[cfg(not(feature = "pjrt"))]
fn cmd_generate_pjrt(_args: &Args) -> Result<()> {
    Err(pjrt_unavailable("generate"))
}

// ---------------------------------------------------------------------------
// native decode measurement (variants + bench-decode)
// ---------------------------------------------------------------------------

struct NativeDecodeReport {
    name: String,
    /// FFN mode name ("dense" | "tardis" | "tardis_reference").
    mode: &'static str,
    /// Predictor routing the timed run (tardis variants only).
    predictor: Option<PredictorKind>,
    mean_ms: f64,
    p50_ms: f64,
    fallback_rate: Option<f64>,
    fixed_neurons: Option<u64>,
    compression_ratio: Option<f64>,
    /// Routing quality of (norm, quantized) on the shared seeded
    /// outlier workload at this variant's fold configuration.
    routing: Option<(RoutingQuality, RoutingQuality)>,
}

/// Time `steps` full decode steps (all slots active) on a freshly built
/// native model; `warmup` untimed steps let the online outlier predictor
/// settle first.
fn measure_native_decode(
    cfg: &NativeModelConfig,
    args: &Args,
    variant: &str,
    steps: usize,
    warmup: usize,
) -> Result<NativeDecodeReport> {
    let mode = mode_with_overrides(args, native_mode(variant)?)?;
    let (predictor, routing) = match &mode {
        FfnMode::Tardis(t) => {
            (Some(t.predictor), Some(measure_routing_quality(cfg, t)))
        }
        _ => (None, None),
    };
    let mut model = NativeModel::new(cfg.clone(), &mode);
    let tokens: Vec<i32> =
        (0..cfg.batch).map(|b| ((7 * b + 3) % cfg.vocab) as i32).collect();
    let mut lat = Samples::new();
    for s in 0..warmup + steps {
        let p = (s % cfg.max_seq) as i32;
        let pos = vec![p; cfg.batch];
        let t0 = std::time::Instant::now();
        let _ = model.decode(&tokens, &pos)?;
        if s >= warmup {
            lat.push(t0.elapsed().as_secs_f64() * 1e3);
        }
    }
    Ok(NativeDecodeReport {
        name: variant.to_string(),
        mode: model.ffn_mode_name(),
        predictor,
        mean_ms: lat.mean(),
        p50_ms: lat.percentile(50.0),
        fallback_rate: model.ffn_telemetry().and_then(|t| t.fallback_rate()),
        fixed_neurons: model.ffn_telemetry().map(|t| t.fixed_neurons),
        compression_ratio: model.fold_compression_ratio(),
        routing,
    })
}

struct SpecDecodeReport {
    name: String,
    k: usize,
    /// Fraction of drafted tokens the verify forward accepted.
    acceptance: Option<f64>,
    plain_tok_s: f64,
    spec_tok_s: f64,
}

/// Single-stream speculative-vs-plain measurement through the full
/// engine: one greedy request decoded end to end, once with speculation
/// off and once drafting `k` tokens per step through the forced-fold
/// path. Single-stream is the scenario continuous batching cannot
/// speed up, so this is where self-speculation has to earn its keep.
fn measure_speculative(
    cfg: &NativeModelConfig,
    args: &Args,
    variant: &str,
    k: usize,
    steps: usize,
) -> Result<SpecDecodeReport> {
    let mode = mode_with_overrides(args, native_mode(variant)?)?;
    let run = |spec_k: usize| -> Result<(f64, Option<f64>)> {
        let model = NativeModel::new(cfg.clone(), &mode);
        let ecfg = EngineConfig {
            speculate_k: spec_k,
            speculate_adaptive: args.bool("speculate-adaptive"),
            prefix_cache: false,
            ..Default::default()
        };
        let mut e = InferenceEngine::new(model, ecfg);
        let prompt: Vec<i32> =
            (0..8).map(|t| ((5 * t + 2) % cfg.vocab) as i32).collect();
        // Untimed warm request: settles the online predictor and the
        // scratch arena, exactly like the plain bench's warmup steps.
        let warm = SamplingParams { max_tokens: 8, ..Default::default() };
        e.generate_sequential(prompt.clone(), warm)?;
        let params = SamplingParams { max_tokens: steps, ..Default::default() };
        let t0 = std::time::Instant::now();
        let c = e.generate_sequential(prompt, params)?;
        let dt = t0.elapsed().as_secs_f64();
        Ok((c.tokens.len() as f64 / dt, e.stats.spec_acceptance()))
    };
    let (plain_tok_s, _) = run(0)?;
    let (spec_tok_s, acceptance) = run(k)?;
    Ok(SpecDecodeReport {
        name: variant.to_string(),
        k,
        acceptance,
        plain_tok_s,
        spec_tok_s,
    })
}

fn print_spec_row(r: &SpecDecodeReport) {
    let acc = r
        .acceptance
        .map(|a| format!("{:5.1}%", a * 100.0))
        .unwrap_or_else(|| "    -".to_string());
    println!(
        "  {:10} plain {:8.1} tok/s  speculative {:8.1} tok/s ({:.2}x)  \
         acceptance {}",
        r.name,
        r.plain_tok_s,
        r.spec_tok_s,
        r.spec_tok_s / r.plain_tok_s,
        acc,
    );
}

/// `TARDIS_ASSERT_SPEC_SPEEDUP` gate: the best variant's speculative
/// tokens/s must strictly beat its own plain decode. On failure the
/// losing rows are re-measured once — keeping the better plain and
/// better speculative throughput of the two runs — before failing, the
/// same jitter guard the TTFT and goodput gates use.
fn assert_spec_speedup(
    cfg: &NativeModelConfig,
    args: &Args,
    reports: &mut [SpecDecodeReport],
    k: usize,
    steps: usize,
) -> Result<()> {
    let beats = |r: &SpecDecodeReport| r.spec_tok_s > r.plain_tok_s;
    if !reports.iter().any(beats) {
        for r in reports.iter_mut() {
            let rerun = measure_speculative(cfg, args, &r.name, k, steps)?;
            r.plain_tok_s = r.plain_tok_s.min(rerun.plain_tok_s);
            r.spec_tok_s = r.spec_tok_s.max(rerun.spec_tok_s);
            r.acceptance = rerun.acceptance.or(r.acceptance);
        }
    }
    match reports.iter().filter(|r| beats(r)).max_by(|a, b| {
        let ra = a.spec_tok_s / a.plain_tok_s;
        let rb = b.spec_tok_s / b.plain_tok_s;
        ra.total_cmp(&rb)
    }) {
        Some(best) => {
            println!(
                "spec speedup check: {} speculative {:.1} tok/s > plain {:.1} \
                 ({:.2}x, acceptance {:.1}%)",
                best.name,
                best.spec_tok_s,
                best.plain_tok_s,
                best.spec_tok_s / best.plain_tok_s,
                best.acceptance.unwrap_or(0.0) * 100.0,
            );
            Ok(())
        }
        None => Err(anyhow!(
            "speculative decode (k={k}) failed to beat plain decode on every \
             variant, even after one re-measure"
        )),
    }
}

/// Precision/recall of both predictors against ground-truth range
/// violations at the model's FFN shape, via the shared
/// [`tardis::ffn::compare_predictors`] harness (the same one the
/// `predictor_quality` regression test asserts on, so the bench numbers
/// and the test measure the same workload).
fn measure_routing_quality(
    cfg: &NativeModelConfig,
    t: &TardisFfnConfig,
) -> (RoutingQuality, RoutingQuality) {
    use std::sync::Arc;
    use tardis::ffn::{compare_predictors, DenseFfn};
    let (d, h) = (cfg.d_model, cfg.d_ff);
    let mut rng = tardis::util::rng::Rng::new(0x0074_D150);
    let scale = 1.0 / (d as f64).sqrt();
    let dense = DenseFfn::new(
        Arc::new((0..d * h).map(|_| (rng.normal() * scale) as f32).collect()),
        Arc::new((0..h).map(|_| (rng.normal() * 0.05) as f32).collect()),
        Arc::new((0..h * d).map(|_| (rng.normal() * scale) as f32).collect()),
        Arc::new(vec![0.0; d]),
        d,
        h,
    );
    let c = compare_predictors(dense, t, &mut rng);
    (c.norm, c.quantized)
}

/// Print one measured-vs-theoretical table row; returns the measured
/// speedup vs dense (None for the dense row itself).
fn print_native_row(
    r: &NativeDecodeReport,
    dense_mean: Option<f64>,
    cfg: &NativeModelConfig,
    ctx: usize,
) -> Option<f64> {
    let speedup = match dense_mean {
        Some(d) if r.compression_ratio.is_some() => Some(d / r.mean_ms),
        _ => None,
    };
    let (theory_ffn, theory_e2e) = match r.compression_ratio {
        Some(ratio) => {
            let fix = r.fallback_rate.unwrap_or(0.0);
            let (f, e) = costmodel::tardis_speedup(
                &costmodel::TINY_GELU,
                &costmodel::CPU_1CORE,
                cfg.batch,
                ctx,
                ratio,
                fix,
            );
            (format!("{f:5.2}x"), format!("{e:5.2}x"))
        }
        None => ("    -".to_string(), "    -".to_string()),
    };
    println!(
        "  {:10} {:9} mean {:8.3} ms  p50 {:8.3}  speedup {}  fallback {}  \
         theory ffn {} e2e {}",
        r.name,
        r.predictor.map(PredictorKind::name).unwrap_or("-"),
        r.mean_ms,
        r.p50_ms,
        speedup
            .map(|s| format!("{s:5.2}x"))
            .unwrap_or_else(|| "    -".to_string()),
        r.fallback_rate
            .map(|f| format!("{:5.1}%", f * 100.0))
            .unwrap_or_else(|| "    -".to_string()),
        theory_ffn,
        theory_e2e,
    );
    speedup
}

/// One routing-quality line per tardis variant: both predictors against
/// the same ground truth.
fn print_routing_rows(reports: &[NativeDecodeReport]) {
    let any = reports.iter().any(|r| r.routing.is_some());
    if !any {
        return;
    }
    println!(
        "routing quality vs ground-truth range violations \
         (seeded direction-dependent-outlier workload):"
    );
    for r in reports {
        let Some((qn, qq)) = r.routing else { continue };
        println!(
            "  {:10} norm      P {:4.2}  R {:4.2}  flag {:5.1}%   (true OOR {:4.1}%)",
            r.name,
            qn.precision,
            qn.recall,
            qn.flag_rate * 100.0,
            qn.true_oor_rate * 100.0,
        );
        println!(
            "  {:10} quantized P {:4.2}  R {:4.2}  flag {:5.1}%",
            "", qq.precision, qq.recall, qq.flag_rate * 100.0,
        );
    }
}

/// Single-thread GEMM microbenchmarks at the configured FFN
/// up-projection shape: GFLOP/s on the active and (forced) portable
/// dispatch paths plus the pre-PR scalar reference, and — because
/// single-token decode is bandwidth-bound — bytes-moved/token with
/// effective GB/s for the rows=1 step, f32 panels vs the fused 4-bit
/// proxy GEMM.
struct GemmBench {
    /// The dispatch path the process selected (`KernelDispatch::name`).
    isa: &'static str,
    /// Packed f32 GEMM on the active path, rows = batch.
    packed_gflops: f64,
    /// Same shape forced onto the portable tiles.
    portable_gflops: f64,
    /// The pre-PR scalar reference kernel.
    naive_gflops: f64,
    /// rows=1 f32: panel + x + y bytes touched per decoded token.
    f32_bytes_per_token: f64,
    f32_gbps: f64,
    /// rows=1 fused 4-bit proxy GEMM (group 32), same accounting.
    q_gflops: f64,
    q_bytes_per_token: f64,
    q_gbps: f64,
    /// f32 bytes over fused bytes: the fused path's traffic advantage
    /// vs widening the codes to an f32 matrix (shape-determined).
    q_bytes_ratio: f64,
}

fn measure_gemm_bench(cfg: &NativeModelConfig) -> GemmBench {
    use tardis::bench::black_box;
    use tardis::ffn::kernels::{
        matmul_naive, matmul_q_with, matmul_with, Epilogue, KernelDispatch, PackedMatrix,
    };
    use tardis::ffn::QuantizedProxy;
    let (d, h) = (cfg.d_model, cfg.d_ff);
    let batch = cfg.batch.max(1);
    let disp = KernelDispatch::active();
    let mut rng = tardis::util::rng::Rng::new(0xBE9C);
    let x: Vec<f32> = (0..batch * d).map(|_| rng.normal() as f32).collect();
    let w: Vec<f32> = (0..d * h).map(|_| rng.normal() as f32).collect();
    let bias: Vec<f32> = (0..h).map(|_| rng.normal() as f32).collect();
    let packed = PackedMatrix::pack(&w, d, h);
    let proxy = QuantizedProxy::quantize(&w, d, h, h, 4, 32);
    let mut y = vec![0f32; batch * h];
    let flops = 2.0 * (batch * d * h) as f64;
    let time = |f: &mut dyn FnMut()| {
        for _ in 0..20 {
            f();
        }
        let iters = 300;
        let t0 = std::time::Instant::now();
        for _ in 0..iters {
            f();
        }
        t0.elapsed().as_secs_f64() / iters as f64
    };
    let t_packed = time(&mut || {
        matmul_with(disp, None, &x, batch, &packed, Epilogue::Bias(&bias), &mut y);
        black_box(&y);
    });
    let t_portable = time(&mut || {
        let p = KernelDispatch::Portable;
        matmul_with(p, None, &x, batch, &packed, Epilogue::Bias(&bias), &mut y);
        black_box(&y);
    });
    let t_naive = time(&mut || {
        black_box(matmul_naive(&x, batch, d, &w, h, Some(&bias)));
    });
    // rows=1 decode-step bandwidth probes: one token streams the whole
    // operand once, so bytes/token = resident operand + x + y.
    let x1 = &x[..d];
    let mut y1 = vec![0f32; h];
    let t_f32_1 = time(&mut || {
        matmul_with(disp, None, x1, 1, &packed, Epilogue::Bias(&bias), &mut y1);
        black_box(&y1);
    });
    let t_q_1 = time(&mut || {
        matmul_q_with(disp, None, x1, 1, proxy.panels(), Epilogue::Bias(&bias), &mut y1);
        black_box(&y1);
    });
    let io = ((d + h) * 4) as f64;
    let f32_bytes = packed.resident_bytes() as f64 + io;
    let q_bytes = proxy.resident_bytes() as f64 + io;
    GemmBench {
        isa: disp.name(),
        packed_gflops: flops / t_packed / 1e9,
        portable_gflops: flops / t_portable / 1e9,
        naive_gflops: flops / t_naive / 1e9,
        f32_bytes_per_token: f32_bytes,
        f32_gbps: f32_bytes / t_f32_1 / 1e9,
        q_gflops: 2.0 * (d * h) as f64 / t_q_1 / 1e9,
        q_bytes_per_token: q_bytes,
        q_gbps: q_bytes / t_q_1 / 1e9,
        q_bytes_ratio: f32_bytes / q_bytes,
    }
}

/// Write the machine-readable per-PR perf record next to the printed
/// table (BENCH_native_ffn.json, or $TARDIS_BENCH_JSON).
///
/// Merges into the existing file instead of clobbering it: other
/// suites own sibling top-level keys (`coordinator`, `native_ffn`)
/// and must survive a bench-decode rerun. Only the keys this suite
/// owns are overwritten.
fn write_bench_json(
    cfg: &NativeModelConfig,
    reports: &[NativeDecodeReport],
    dense_mean: Option<f64>,
    g: &GemmBench,
    spec: &[SpecDecodeReport],
) {
    use tardis::util::json::Json;
    let num = Json::Num;
    let path = std::env::var("TARDIS_BENCH_JSON")
        .unwrap_or_else(|_| "BENCH_native_ffn.json".to_string());
    let mut root = match std::fs::read_to_string(&path)
        .ok()
        .and_then(|text| Json::parse(&text).ok())
    {
        Some(Json::Obj(map)) => map,
        _ => std::collections::BTreeMap::new(),
    };
    root.insert("suite".to_string(), Json::Str("bench_decode".to_string()));
    let mut shape = std::collections::BTreeMap::new();
    shape.insert("d_model".to_string(), num(cfg.d_model as f64));
    shape.insert("d_ff".to_string(), num(cfg.d_ff as f64));
    shape.insert("n_layers".to_string(), num(cfg.n_layers as f64));
    shape.insert("batch".to_string(), num(cfg.batch as f64));
    root.insert("shape".to_string(), Json::Obj(shape));
    let mut gemm = std::collections::BTreeMap::new();
    gemm.insert("isa".to_string(), Json::Str(g.isa.to_string()));
    gemm.insert("packed_gflops".to_string(), num(g.packed_gflops));
    gemm.insert("portable_gflops".to_string(), num(g.portable_gflops));
    gemm.insert("naive_gflops".to_string(), num(g.naive_gflops));
    gemm.insert(
        "packed_vs_naive".to_string(),
        num(g.packed_gflops / g.naive_gflops),
    );
    gemm.insert(
        "f32_bytes_per_token".to_string(),
        num(g.f32_bytes_per_token),
    );
    gemm.insert("f32_gbps".to_string(), num(g.f32_gbps));
    gemm.insert("fused_q4_gflops".to_string(), num(g.q_gflops));
    gemm.insert(
        "fused_q4_bytes_per_token".to_string(),
        num(g.q_bytes_per_token),
    );
    gemm.insert("fused_q4_gbps".to_string(), num(g.q_gbps));
    gemm.insert("fused_q4_bytes_ratio".to_string(), num(g.q_bytes_ratio));
    root.insert("gemm".to_string(), Json::Obj(gemm));
    let mut rows = Vec::new();
    for r in reports {
        let mut o = std::collections::BTreeMap::new();
        o.insert("variant".to_string(), Json::Str(r.name.clone()));
        o.insert("mode".to_string(), Json::Str(r.mode.to_string()));
        o.insert("mean_ms".to_string(), num(r.mean_ms));
        o.insert("p50_ms".to_string(), num(r.p50_ms));
        o.insert(
            "tokens_per_s".to_string(),
            num(cfg.batch as f64 / (r.mean_ms * 1e-3)),
        );
        if let (Some(dm), Some(_)) = (dense_mean, r.compression_ratio) {
            o.insert("speedup_vs_dense".to_string(), num(dm / r.mean_ms));
        }
        if let Some(f) = r.fallback_rate {
            o.insert("fallback_rate".to_string(), num(f));
        }
        if let Some(c) = r.compression_ratio {
            o.insert("compression".to_string(), num(c));
        }
        if let Some(p) = r.predictor {
            o.insert("predictor".to_string(), Json::Str(p.name().to_string()));
        }
        if let Some(n) = r.fixed_neurons {
            o.insert("fixed_neurons".to_string(), num(n as f64));
        }
        if let Some((qn, qq)) = r.routing {
            let mut routing = std::collections::BTreeMap::new();
            for (tag, q) in [("norm", qn), ("quantized", qq)] {
                let mut ro = std::collections::BTreeMap::new();
                ro.insert("precision".to_string(), num(q.precision));
                ro.insert("recall".to_string(), num(q.recall));
                ro.insert("flag_rate".to_string(), num(q.flag_rate));
                routing.insert(tag.to_string(), Json::Obj(ro));
            }
            routing.insert("true_oor_rate".to_string(), num(qn.true_oor_rate));
            o.insert("routing".to_string(), Json::Obj(routing));
        }
        rows.push(Json::Obj(o));
    }
    root.insert("variants".to_string(), Json::Arr(rows));
    if !spec.is_empty() {
        // decode.speculative is owned by the --speculate-k measurement:
        // merge into whatever else lives under "decode" (and leave the
        // whole key alone when speculation was not measured) so sibling
        // records survive a plain bench-decode rerun.
        let mut decode = match root.remove("decode") {
            Some(Json::Obj(map)) => map,
            _ => std::collections::BTreeMap::new(),
        };
        let mut sp = std::collections::BTreeMap::new();
        sp.insert("k".to_string(), num(spec[0].k as f64));
        let mut sp_rows = Vec::new();
        for r in spec {
            let mut o = std::collections::BTreeMap::new();
            o.insert("variant".to_string(), Json::Str(r.name.clone()));
            if let Some(a) = r.acceptance {
                o.insert("acceptance".to_string(), num(a));
            }
            o.insert("plain_tokens_per_s".to_string(), num(r.plain_tok_s));
            o.insert("spec_tokens_per_s".to_string(), num(r.spec_tok_s));
            o.insert(
                "speedup_vs_plain".to_string(),
                num(r.spec_tok_s / r.plain_tok_s),
            );
            sp_rows.push(Json::Obj(o));
        }
        sp.insert("variants".to_string(), Json::Arr(sp_rows));
        sp.insert(
            "note".to_string(),
            Json::Str(
                "single greedy stream, forced-fold drafts, one batched \
                 verify forward per step"
                    .to_string(),
            ),
        );
        decode.insert("speculative".to_string(), Json::Obj(sp));
        root.insert("decode".to_string(), Json::Obj(decode));
    }
    root.insert(
        "note".to_string(),
        Json::Str(
            "measured by `tardis bench-decode --backend native`; gemm numbers are \
             single-thread at the FFN up-projection shape, bandwidth at rows=1"
                .to_string(),
        ),
    );
    let body = format!("{}\n", Json::Obj(root));
    match std::fs::write(&path, body) {
        Ok(()) => println!("wrote {path}"),
        Err(e) => eprintln!("could not write {path}: {e}"),
    }
}

fn bench_native_table(args: &Args, names: &[String], emit_json: bool) -> Result<()> {
    let cfg = native_model_cfg(args)?;
    let steps = args.usize("steps", 64)?;
    let warmup = args.usize("warmup", 8)?;
    let ctx = warmup + steps / 2;
    println!(
        "native decode-step latency ({} steps after {} warmup, batch {}, \
         d={}, ffn={}, {} layers):",
        steps, warmup, cfg.batch, cfg.d_model, cfg.d_ff, cfg.n_layers
    );
    // Measure everything first: the dense baseline is found by mode, not
    // by listing order, so `--variants tardis80,dense` and tardis-ref
    // rows cannot skew the speedup column or the --assert-speedup gate.
    let mut reports = Vec::new();
    for name in names {
        reports.push(measure_native_decode(&cfg, args, name, steps, warmup)?);
    }
    let dense_mean = reports.iter().find(|r| r.mode == "dense").map(|r| r.mean_ms);
    let mut best_speedup: Option<f64> = None;
    for r in &reports {
        let speedup = print_native_row(r, dense_mean, &cfg, ctx);
        if let Some(s) = speedup {
            best_speedup =
                Some(best_speedup.map_or(s, |b: f64| b.max(s)));
        }
    }
    print_routing_rows(&reports);
    let g = measure_gemm_bench(&cfg);
    println!(
        "gemm single-thread [{}x{}]x[{}x{}] ({} path): packed {:.2} GFLOP/s, \
         portable {:.2}, pre-PR scalar {:.2} ({:.2}x)",
        cfg.batch,
        cfg.d_model,
        cfg.d_model,
        cfg.d_ff,
        g.isa,
        g.packed_gflops,
        g.portable_gflops,
        g.naive_gflops,
        g.packed_gflops / g.naive_gflops,
    );
    println!(
        "decode rows=1 traffic: f32 {:.0} B/token ({:.2} GB/s effective), \
         fused 4-bit proxy {:.0} B/token ({:.2} GB/s, {:.2} GFLOP/s) — \
         {:.2}x fewer bytes than widened f32",
        g.f32_bytes_per_token,
        g.f32_gbps,
        g.q_bytes_per_token,
        g.q_gbps,
        g.q_gflops,
        g.q_bytes_ratio,
    );
    let spec_k = args.usize("speculate-k", 0)?;
    let mut spec_reports = Vec::new();
    if spec_k > 0 {
        println!(
            "speculative decode (single stream, forced-fold drafts, k={spec_k}, \
             {steps} tokens):"
        );
        for name in names {
            let r = measure_speculative(&cfg, args, name, spec_k, steps)?;
            print_spec_row(&r);
            spec_reports.push(r);
        }
    }
    if emit_json {
        write_bench_json(&cfg, &reports, dense_mean, &g, &spec_reports);
    }
    let spec_gate = args.bool("assert-spec-speedup")
        || std::env::var("TARDIS_ASSERT_SPEC_SPEEDUP").is_ok_and(|v| v == "1");
    if spec_gate {
        anyhow::ensure!(
            spec_k > 0,
            "--assert-spec-speedup needs --speculate-k > 0"
        );
        assert_spec_speedup(&cfg, args, &mut spec_reports, spec_k, steps)?;
    }
    if let Some(min) = args.opt_str("assert-speedup") {
        let min: f64 = min
            .parse()
            .map_err(|_| anyhow!("--assert-speedup expects a number"))?;
        let best = best_speedup.ok_or_else(|| {
            anyhow!("--assert-speedup needs dense plus a tardis variant")
        })?;
        if best < min {
            return Err(anyhow!(
                "measured tardis speedup {best:.2}x below required {min:.2}x"
            ));
        }
        println!("speedup check: best {best:.2}x >= required {min:.2}x");
    }
    if let Some(min) = args.opt_str("assert-gflops") {
        let min: f64 = min
            .parse()
            .map_err(|_| anyhow!("--assert-gflops expects a number"))?;
        if g.packed_gflops < min {
            return Err(anyhow!(
                "packed GEMM {:.2} GFLOP/s below required {min:.2}",
                g.packed_gflops
            ));
        }
        // On a SIMD path the explicit kernels must not lose to the
        // portable tiles they replaced, and the fused proxy GEMM must
        // keep its ≥2x traffic advantage over a widened f32 matrix.
        if g.isa != "portable" && g.packed_gflops < g.portable_gflops {
            return Err(anyhow!(
                "{} path {:.2} GFLOP/s below portable {:.2}",
                g.isa,
                g.packed_gflops,
                g.portable_gflops
            ));
        }
        if g.q_bytes_ratio < 2.0 {
            return Err(anyhow!(
                "fused 4-bit proxy moves only {:.2}x fewer bytes than \
                 widened f32 (need >= 2x)",
                g.q_bytes_ratio
            ));
        }
        println!(
            "gflops check: packed {:.2} >= required {min:.2} on the {} path \
             (portable {:.2}); fused bytes ratio {:.2}x >= 2x",
            g.packed_gflops, g.isa, g.portable_gflops, g.q_bytes_ratio
        );
    }
    Ok(())
}

fn cmd_bench_decode(args: &Args) -> Result<()> {
    match backend(args)? {
        BackendKind::Native => {
            let names = args
                .list("variants", &["dense", "tardis50", "tardis70", "tardis80"]);
            bench_native_table(args, &names, true)
        }
        BackendKind::Mock => Err(anyhow!(
            "bench-decode on the mock backend measures nothing; \
             use --backend native"
        )),
        BackendKind::Pjrt => cmd_bench_decode_pjrt(args),
    }
}

#[cfg(feature = "pjrt")]
fn cmd_bench_decode_pjrt(args: &Args) -> Result<()> {
    let manifest = Manifest::load(&manifest_path(args))?;
    let engine = Engine::cpu()?;
    let steps = args.usize("steps", 64)?;
    let variants =
        args.list("variants", &["dense", "tardis50", "tardis70", "tardis80"]);
    println!("decode-step latency ({} steps, batch {}):", steps, manifest.batch);
    let mut dense_mean = None;
    for vname in &variants {
        let v = engine.load_variant(&manifest, vname, Some(&["decode"]))?;
        let mut model = PjrtModel::new(
            &engine,
            v,
            manifest.batch,
            manifest.model.max_seq,
            manifest.model.vocab,
            manifest.prefill_buckets.clone(),
        )?;
        let tokens = vec![1i32; manifest.batch];
        let mut lat = Samples::new();
        for s in 0..steps {
            let pos: Vec<i32> = vec![s as i32; manifest.batch];
            let t0 = std::time::Instant::now();
            let _ = model.decode(&tokens, &pos)?;
            lat.push(t0.elapsed().as_secs_f64() * 1e3);
        }
        let mean = lat.mean();
        if vname == "dense" {
            dense_mean = Some(mean);
        }
        let speedup = dense_mean.map(|d| d / mean).unwrap_or(f64::NAN);
        println!(
            "  {:10} mean {:8.2} ms  p50 {:8.2}  speedup vs dense {:.2}x",
            vname,
            mean,
            lat.percentile(50.0),
            speedup
        );
    }
    Ok(())
}

#[cfg(not(feature = "pjrt"))]
fn cmd_bench_decode_pjrt(_args: &Args) -> Result<()> {
    Err(pjrt_unavailable("bench-decode"))
}

// ---------------------------------------------------------------------------
// bench-trace
// ---------------------------------------------------------------------------

/// Trace-driven workload replay on the deterministic virtual clock:
/// per-tier SLO goodput for each requested scheduler policy over one
/// workload, merged into BENCH_native_ffn.json under `coordinator.slo`,
/// plus the edf-vs-fifo goodput regression gate CI runs with
/// `TARDIS_ASSERT_GOODPUT=1` on the committed overload fixture.
fn cmd_bench_trace(args: &Args) -> Result<()> {
    let (events, source) = match args.opt_str("trace") {
        Some(path) => {
            let text = std::fs::read_to_string(&path)
                .map_err(|e| anyhow!("could not read trace {path:?}: {e}"))?;
            (trace::load_jsonl(&text)?, path)
        }
        None => {
            let preset = args.str("preset", "overload");
            let mut spec = match preset.as_str() {
                "overload" => trace::TraceSpec::overload_preset(),
                "default" => trace::TraceSpec::default(),
                other => {
                    return Err(anyhow!(
                        "unknown preset {other:?} (expected overload|default)"
                    ))
                }
            };
            spec.seed = args.usize("seed", spec.seed as usize)? as u64;
            spec.sessions = args.usize("sessions", spec.sessions)?;
            (trace::generate(&spec), format!("generated:{preset}"))
        }
    };
    if events.is_empty() {
        return Err(anyhow!("trace contains no events"));
    }
    if let Some(out) = args.opt_str("trace-out") {
        std::fs::write(&out, trace::dump_jsonl(&events))
            .map_err(|e| anyhow!("could not write {out}: {e}"))?;
        println!("wrote trace fixture {out} ({} events)", events.len());
    }

    let replay_cfg = trace::ReplayConfig {
        overload: OverloadPolicy {
            degrade_at: args.f64("degrade-at", 0.5)?,
            shed_at: args.f64("shed-at", 0.9)?,
            tier_max: args.usize("tier-max", 0)? as i32,
        },
        step_cost_us: args.usize("step-cost-us", 1000)? as u64,
        seed: args.usize("seed", 0)? as u64,
    };
    let names = args.list("policies", &["fifo", "edf"]);
    let mut policies = Vec::new();
    for name in &names {
        policies.push(PolicyKind::parse(name).ok_or_else(|| {
            anyhow!("unknown policy {name:?} (expected fifo|spf|priority|edf)")
        })?);
    }

    let base_cfg = engine_config(args)?;
    let slots = args.usize("slots", 4)?;
    let max_seq = args.usize("max-seq", 256)?;
    let run = |policy: PolicyKind| -> Result<trace::ReplayReport> {
        let mut cfg = base_cfg.clone();
        cfg.scheduler.policy = policy;
        let mut engine = InferenceEngine::new(
            MockModel::new(slots, max_seq, 256, vec![16, 64]),
            cfg,
        );
        trace::replay(&mut engine, &events, &replay_cfg)
    };

    println!(
        "trace replay: {} events from {}, {}us/step, ladder degrade@{} \
         shed@{} (tiers <= priority {})",
        events.len(),
        source,
        replay_cfg.step_cost_us,
        replay_cfg.overload.degrade_at,
        replay_cfg.overload.shed_at,
        replay_cfg.overload.tier_max,
    );
    println!("  policy     goodput   met/total   shed  degraded  makespan_ms");
    let mut results: Vec<(PolicyKind, trace::ReplayReport)> = Vec::new();
    for pk in &policies {
        let report = run(*pk)?;
        let met: usize = report.tiers.iter().map(|t| t.met).sum();
        println!(
            "  {:9} {:7.3}  {:5}/{:<5}  {:5}  {:8}  {:11.1}",
            pk.name(),
            report.goodput(),
            met,
            report.outcomes.len(),
            report.shed(),
            report.degraded(),
            report.makespan_us as f64 / 1e3,
        );
        for t in &report.tiers {
            println!(
                "      tier {}: goodput {:.3} ({}/{} met, {} shed, {} degraded)",
                t.tier,
                t.goodput(),
                t.met,
                t.total,
                t.shed,
                t.degraded,
            );
        }
        results.push((*pk, report));
    }
    write_slo_json(&source, &results);

    let gate = args.bool("assert-goodput")
        || std::env::var("TARDIS_ASSERT_GOODPUT").is_ok_and(|v| v == "1");
    if gate {
        let find = |kind: PolicyKind| {
            results.iter().find(|(p, _)| *p == kind).map(|(_, r)| r.goodput())
        };
        let (Some(mut fifo), Some(mut edf)) =
            (find(PolicyKind::Fifo), find(PolicyKind::Edf))
        else {
            return Err(anyhow!(
                "--assert-goodput needs both fifo and edf in --policies"
            ));
        };
        if edf <= fifo {
            // Re-measure both once before failing. Replay is
            // deterministic on the virtual clock, so a flip here means
            // a real regression, but keep the shape of the other bench
            // gates: loosen in both directions (best edf, worst fifo).
            fifo = fifo.min(run(PolicyKind::Fifo)?.goodput());
            edf = edf.max(run(PolicyKind::Edf)?.goodput());
        }
        if edf <= fifo {
            eprintln!(
                "FAIL: edf goodput {edf:.3} must strictly exceed fifo \
                 {fifo:.3} on the overload trace"
            );
            std::process::exit(1);
        }
        println!("goodput check: edf {edf:.3} > fifo {fifo:.3}");
    }
    Ok(())
}

/// Merge the per-policy goodput summaries into the shared perf record
/// under `coordinator.slo`. Sibling keys — including the rest of the
/// `coordinator` object written by the scheduler bench — survive.
fn write_slo_json(source: &str, results: &[(PolicyKind, trace::ReplayReport)]) {
    use tardis::util::json::Json;
    let path = std::env::var("TARDIS_BENCH_JSON")
        .unwrap_or_else(|_| "BENCH_native_ffn.json".to_string());
    let mut root = match std::fs::read_to_string(&path)
        .ok()
        .and_then(|text| Json::parse(&text).ok())
    {
        Some(Json::Obj(map)) => map,
        _ => std::collections::BTreeMap::new(),
    };
    let mut coord = match root.get("coordinator") {
        Some(Json::Obj(map)) => map.clone(),
        _ => std::collections::BTreeMap::new(),
    };
    let mut slo = std::collections::BTreeMap::new();
    slo.insert("trace".to_string(), Json::Str(source.to_string()));
    let mut by_policy = std::collections::BTreeMap::new();
    for (pk, report) in results {
        by_policy.insert(pk.name().to_string(), report.summary_json());
    }
    slo.insert("policies".to_string(), Json::Obj(by_policy));
    slo.insert(
        "note".to_string(),
        Json::Str(
            "per-tier SLO goodput from `tardis bench-trace` on the virtual \
             clock; goodput = fraction of requests served within both their \
             TTFT and TPOT deadlines (shed requests count as missed)"
                .to_string(),
        ),
    );
    coord.insert("slo".to_string(), Json::Obj(slo));
    root.insert("coordinator".to_string(), Json::Obj(coord));
    let body = format!("{}\n", Json::Obj(root));
    match std::fs::write(&path, body) {
        Ok(()) => println!("wrote {path}"),
        Err(e) => eprintln!("could not write {path}: {e}"),
    }
}

// ---------------------------------------------------------------------------
// variants
// ---------------------------------------------------------------------------

fn cmd_variants(args: &Args) -> Result<()> {
    print_manifest_variants(args);
    // Measured native table next to the theoretical costmodel numbers,
    // so theory and measurement land in one place.
    let names = args
        .list("variants", &["dense", "tardis50", "tardis70", "tardis80"]);
    bench_native_table(args, &names, false)
}

fn print_manifest_variants(args: &Args) {
    match Manifest::load(&manifest_path(args)) {
        Err(e) => eprintln!("[variants] no artifact manifest ({e:#})"),
        Ok(manifest) => {
            println!(
                "model {} (d={}, L={}, h={}, act={}), batch {}, max_seq {}",
                manifest.model.name,
                manifest.model.d_model,
                manifest.model.n_layers,
                manifest.model.d_ff,
                manifest.model.act,
                manifest.batch,
                manifest.model.max_seq
            );
            for v in &manifest.variants {
                println!(
                    "  {:10} mode={:6} ratio={:5.1}% fix_capacity={:4} execs={}",
                    v.name,
                    v.ffn_mode,
                    v.compression_ratio * 100.0,
                    v.fix_capacity,
                    v.executables.len()
                );
            }
        }
    }
}

// ---------------------------------------------------------------------------
// PJRT helpers
// ---------------------------------------------------------------------------

#[cfg(feature = "pjrt")]
fn load_engine<'e>(
    engine: &'e Engine,
    manifest: &Manifest,
    variant: &str,
    execs: Option<&[&str]>,
    cfg: EngineConfig,
) -> Result<InferenceEngine<PjrtModel<'e>>> {
    let v = engine.load_variant(manifest, variant, execs)?;
    let model = PjrtModel::new(
        engine,
        v,
        manifest.batch,
        manifest.model.max_seq,
        manifest.model.vocab,
        manifest.prefill_buckets.clone(),
    )?;
    Ok(InferenceEngine::new(model, cfg))
}

#[cfg(feature = "pjrt")]
fn main_exec_tags(manifest: &Manifest) -> Vec<&'static str> {
    let mut tags = vec!["decode"];
    // prefill tags are static strings in the manifest ("prefill16", ...)
    // but we need 'static for the filter; map known buckets.
    for b in &manifest.prefill_buckets {
        match b {
            16 => tags.push("prefill16"),
            64 => tags.push("prefill64"),
            _ => {}
        }
    }
    tags
}

fn main() {
    let args = match Args::from_env(true) {
        Ok(a) => a,
        Err(e) => {
            eprintln!("{e}");
            usage();
        }
    };
    let result = match args.subcommand.as_deref() {
        Some("costmodel") => cmd_costmodel(&args),
        Some("serve") => cmd_serve(&args, None),
        Some("serve-mock") => cmd_serve(&args, Some(BackendKind::Mock)),
        Some("generate") => cmd_generate(&args),
        Some("variants") => cmd_variants(&args),
        Some("bench-decode") => cmd_bench_decode(&args),
        Some("bench-trace") => cmd_bench_trace(&args),
        _ => usage(),
    };
    if let Err(e) = result {
        eprintln!("error: {e:#}");
        std::process::exit(1);
    }
}
