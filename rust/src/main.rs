//! `tardis` CLI — the L3 entrypoint.
//!
//! Subcommands:
//!   costmodel  — print the Fig 1b analytic breakdown (paper-scale model)
//!   serve-mock — TCP server over deterministic mock replicas (std-only;
//!                exercises the scheduler/serving stack without artifacts)
//! With `--features pjrt`:
//!   generate   — load a variant, generate from a prompt, print text+stats
//!   serve      — TCP server (line-delimited JSON) over one or more variants
//!   variants   — list manifest variants and their compression ratios
//!   bench-decode — quick per-variant decode-step timing (full Fig 13 lives
//!                  in `cargo bench --bench fig13_speedup`)

use anyhow::{anyhow, Result};

use tardis::coordinator::engine_loop::{EngineConfig, InferenceEngine};
use tardis::coordinator::model::MockModel;
use tardis::coordinator::router::Router;
use tardis::coordinator::scheduler::PolicyKind;
use tardis::costmodel;
use tardis::util::cli::Args;

#[cfg(feature = "pjrt")]
use tardis::config::Manifest;
#[cfg(feature = "pjrt")]
use tardis::coordinator::model::{PjrtModel, StepModel};
#[cfg(feature = "pjrt")]
use tardis::coordinator::request::SamplingParams;
#[cfg(feature = "pjrt")]
use tardis::runtime::Engine;
#[cfg(feature = "pjrt")]
use tardis::server::protocol::{decode_tokens, encode_text};

fn usage() -> ! {
    eprintln!(
        "usage: tardis <costmodel|serve-mock|generate|serve|variants|bench-decode> [flags]
  (generate/serve/variants/bench-decode need a build with --features pjrt)
  common flags:
    --artifacts DIR        artifacts directory (default: artifacts or $TARDIS_ARTIFACTS)
    --variant NAME         model variant (default: tardis80)
  scheduling flags (serve / serve-mock / generate):
    --policy NAME          admission policy: fifo|spf|priority (default fifo)
    --max-prefills N       concurrent prefill jobs (default 2)
    --chunk-budget N       prefill chunks per iteration (default 2)
    --queue-capacity N     admission queue depth before backpressure (default 64)
  generate:
    --prompt TEXT          prompt (default: \"the quick \")
    --max-tokens N         tokens to generate (default 48)
    --temperature T        sampling temperature (default 0 = greedy)
    --priority N           admission priority (default 0)
  serve / serve-mock:
    --addr HOST:PORT       listen address (default 127.0.0.1:7437)
    --variants A,B         replicas to load (serve default dense,tardis80;
                           serve-mock default mock)
    --max-requests N       exit after N served requests (for scripted runs)
  serve-mock:
    --slots N              KV slots per mock replica (default 4)
    --max-seq N            mock context length (default 256)
  bench-decode:
    --steps N              decode steps to time (default 32)"
    );
    std::process::exit(2);
}

/// Shared scheduler/engine config from the CLI flags.
fn engine_config(args: &Args) -> Result<EngineConfig> {
    let mut cfg = EngineConfig::default();
    if let Some(p) = args.opt_str("policy") {
        cfg.scheduler.policy = PolicyKind::parse(&p).ok_or_else(|| {
            anyhow!("unknown policy {p:?} (expected fifo|spf|priority)")
        })?;
    }
    cfg.scheduler.max_concurrent_prefills =
        args.usize("max-prefills", cfg.scheduler.max_concurrent_prefills)?;
    cfg.scheduler.chunk_budget =
        args.usize("chunk-budget", cfg.scheduler.chunk_budget)?;
    cfg.queue_capacity = args.usize("queue-capacity", cfg.queue_capacity)?;
    Ok(cfg)
}

/// std-only server: mock replicas with the full scheduler stack, for
/// protocol/scheduling experiments without PJRT artifacts.
fn cmd_serve_mock(args: &Args) -> Result<()> {
    let cfg = engine_config(args)?;
    let slots = args.usize("slots", 4)?;
    let max_seq = args.usize("max-seq", 256)?;
    let names = args.list("variants", &["mock"]);
    let replicas = names
        .iter()
        .map(|name| {
            (
                name.clone(),
                InferenceEngine::new(
                    MockModel::new(slots, max_seq, 256, vec![16, 64]),
                    cfg.clone(),
                ),
            )
        })
        .collect();
    let router = Router::new(replicas);
    let addr = args.str("addr", "127.0.0.1:7437");
    let max_requests = parse_max_requests(args)?;
    eprintln!("[serve-mock] policy={} replicas={names:?}",
              cfg.scheduler.policy.name());
    let served = tardis::server::tcp::serve(router, &addr, max_requests)?;
    eprintln!("[serve-mock] done, served {served} requests");
    Ok(())
}

fn parse_max_requests(args: &Args) -> Result<Option<usize>> {
    args.opt_str("max-requests")
        .map(|s| s.parse::<usize>())
        .transpose()
        .map_err(|_| anyhow!("--max-requests expects an integer"))
}

fn cmd_costmodel(_args: &Args) -> Result<()> {
    let b = costmodel::inference_breakdown(
        &costmodel::FALCON_7B, &costmodel::RTX_4090, 1, 91, 178);
    println!("Fig 1b reproduction — Falcon-7B on RTX 4090, 91 prompt + 178 generated tokens");
    println!("  component      share of inference time");
    println!("  MHA I/O        {:5.1}%", b.attn_io * 100.0);
    println!("  MHA compute    {:5.1}%", b.attn_compute * 100.0);
    println!("  FFN I/O        {:5.1}%   (paper: 78.2%)", b.ffn_io * 100.0);
    println!("  FFN compute    {:5.1}%", b.ffn_compute * 100.0);
    println!("  modeled total  {:.2}s", b.total_s);
    println!();
    println!("TARDIS theoretical speedups (decode, ctx 128):");
    for ratio in [0.3, 0.5, 0.7, 0.8] {
        let (ffn, e2e) = costmodel::tardis_speedup(
            &costmodel::FALCON_7B, &costmodel::RTX_4090, 1, 128, ratio, 0.05);
        println!("  ratio {:.0}%: FFN {:.2}x, end-to-end {:.2}x",
                 ratio * 100.0, ffn, e2e);
    }
    Ok(())
}

// ---------------------------------------------------------------------------
// PJRT-backed subcommands (need the real runtime).
// ---------------------------------------------------------------------------

#[cfg(feature = "pjrt")]
fn load_engine<'e>(
    engine: &'e Engine,
    manifest: &Manifest,
    variant: &str,
    execs: Option<&[&str]>,
    cfg: EngineConfig,
) -> Result<InferenceEngine<PjrtModel<'e>>> {
    let v = engine.load_variant(manifest, variant, execs)?;
    let model = PjrtModel::new(
        engine,
        v,
        manifest.batch,
        manifest.model.max_seq,
        manifest.model.vocab,
        manifest.prefill_buckets.clone(),
    )?;
    Ok(InferenceEngine::new(model, cfg))
}

#[cfg(feature = "pjrt")]
fn main_exec_tags(manifest: &Manifest) -> Vec<&'static str> {
    let mut tags = vec!["decode"];
    // prefill tags are static strings in the manifest ("prefill16", ...)
    // but we need 'static for the filter; map known buckets.
    for b in &manifest.prefill_buckets {
        match b {
            16 => tags.push("prefill16"),
            64 => tags.push("prefill64"),
            _ => {}
        }
    }
    tags
}

#[cfg(feature = "pjrt")]
fn cmd_generate(args: &Args) -> Result<()> {
    let manifest = Manifest::load(&manifest_path(args))?;
    let variant = args.str("variant", "tardis80");
    let engine = Engine::cpu()?;
    eprintln!("[generate] platform={} variant={variant}", engine.platform());
    let mut ie = load_engine(&engine, &manifest, &variant,
                             Some(&main_exec_tags(&manifest)),
                             engine_config(args)?)?;
    let prompt = args.str("prompt", "the quick ");
    let params = SamplingParams {
        temperature: args.f64("temperature", 0.0)? as f32,
        top_k: args.usize("top-k", 0)?,
        max_tokens: args.usize("max-tokens", 48)?,
        stop_token: None,
        seed: args.usize("seed", 0)? as u64,
        priority: match args.opt_str("priority") {
            None => 0,
            Some(s) => s.parse::<i32>().map_err(|_| {
                anyhow!("--priority expects an integer, got {s:?}")
            })?,
        },
    };
    let t0 = std::time::Instant::now();
    let c = ie.generate_sequential(encode_text(&prompt), params)?;
    let dt = t0.elapsed().as_secs_f64();
    println!("{}{}", prompt, decode_tokens(&c.tokens));
    eprintln!(
        "[generate] {} tokens in {:.2}s ({:.1} tok/s, decode mean {:.2} ms, \
         compression ratio {:.1}%)",
        c.tokens.len(),
        dt,
        c.tokens.len() as f64 / dt,
        ie.decode_latency_ms.mean(),
        ie.model.compression_ratio() * 100.0
    );
    Ok(())
}

#[cfg(feature = "pjrt")]
fn cmd_serve(args: &Args) -> Result<()> {
    let manifest = Manifest::load(&manifest_path(args))?;
    let engine = Engine::cpu()?;
    let cfg = engine_config(args)?;
    let variants = args.list("variants", &["dense", "tardis80"]);
    let mut replicas = Vec::new();
    for v in &variants {
        eprintln!("[serve] loading {v} ...");
        replicas.push((
            v.clone(),
            load_engine(&engine, &manifest, v, Some(&main_exec_tags(&manifest)),
                        cfg.clone())?,
        ));
    }
    let router = Router::new(replicas);
    let addr = args.str("addr", "127.0.0.1:7437");
    let max_requests = parse_max_requests(args)?;
    let served = tardis::server::tcp::serve(router, &addr, max_requests)?;
    eprintln!("[serve] done, served {served} requests");
    Ok(())
}

#[cfg(feature = "pjrt")]
fn cmd_variants(args: &Args) -> Result<()> {
    let manifest = Manifest::load(&manifest_path(args))?;
    println!("model {} (d={}, L={}, h={}, act={}), batch {}, max_seq {}",
             manifest.model.name, manifest.model.d_model,
             manifest.model.n_layers, manifest.model.d_ff,
             manifest.model.act, manifest.batch, manifest.model.max_seq);
    for v in &manifest.variants {
        println!(
            "  {:10} mode={:6} ratio={:5.1}% fix_capacity={:4} execs={}",
            v.name,
            v.ffn_mode,
            v.compression_ratio * 100.0,
            v.fix_capacity,
            v.executables.len()
        );
    }
    Ok(())
}

#[cfg(feature = "pjrt")]
fn cmd_bench_decode(args: &Args) -> Result<()> {
    let manifest = Manifest::load(&manifest_path(args))?;
    let engine = Engine::cpu()?;
    let steps = args.usize("steps", 32)?;
    let variants = args.list("variants", &["dense", "tardis50", "tardis70", "tardis80"]);
    println!("decode-step latency ({} steps, batch {}):", steps, manifest.batch);
    let mut dense_mean = None;
    for vname in &variants {
        let v = engine.load_variant(&manifest, vname, Some(&["decode"]))?;
        let mut model = PjrtModel::new(&engine, v, manifest.batch,
                                       manifest.model.max_seq,
                                       manifest.model.vocab,
                                       manifest.prefill_buckets.clone())?;
        let tokens = vec![1i32; manifest.batch];
        let mut lat = tardis::util::stats::Samples::new();
        for s in 0..steps {
            let pos: Vec<i32> = vec![s as i32; manifest.batch];
            let t0 = std::time::Instant::now();
            let _ = model.decode(&tokens, &pos)?;
            lat.push(t0.elapsed().as_secs_f64() * 1e3);
        }
        let mean = lat.mean();
        if vname == "dense" {
            dense_mean = Some(mean);
        }
        let speedup = dense_mean.map(|d| d / mean).unwrap_or(f64::NAN);
        println!("  {:10} mean {:8.2} ms  p50 {:8.2}  speedup vs dense {:.2}x",
                 vname, mean, lat.percentile(50.0), speedup);
    }
    Ok(())
}

#[cfg(feature = "pjrt")]
fn manifest_path(args: &Args) -> std::path::PathBuf {
    args.opt_str("artifacts")
        .map(|d| std::path::PathBuf::from(d).join("manifest.json"))
        .unwrap_or_else(Manifest::default_path)
}

fn main() {
    let args = match Args::from_env(true) {
        Ok(a) => a,
        Err(e) => {
            eprintln!("{e}");
            usage();
        }
    };
    let result = match args.subcommand.as_deref() {
        Some("costmodel") => cmd_costmodel(&args),
        Some("serve-mock") => cmd_serve_mock(&args),
        #[cfg(feature = "pjrt")]
        Some("generate") => cmd_generate(&args),
        #[cfg(feature = "pjrt")]
        Some("serve") => cmd_serve(&args),
        #[cfg(feature = "pjrt")]
        Some("variants") => cmd_variants(&args),
        #[cfg(feature = "pjrt")]
        Some("bench-decode") => cmd_bench_decode(&args),
        #[cfg(not(feature = "pjrt"))]
        Some(cmd @ ("generate" | "serve" | "variants" | "bench-decode")) => {
            Err(anyhow!(
                "subcommand {cmd:?} needs the PJRT runtime; rebuild with \
                 `cargo build --features pjrt` (and real xla bindings)"
            ))
        }
        _ => usage(),
    };
    if let Err(e) = result {
        eprintln!("error: {e:#}");
        std::process::exit(1);
    }
}
