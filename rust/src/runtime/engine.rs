//! PJRT engine: compile HLO-text artifacts, keep weights device-resident,
//! thread the KV cache between steps without host round-trips.
//!
//! Pattern (from /opt/xla-example/load_hlo): `PjRtClient::cpu()` →
//! `HloModuleProto::from_text_file` → `client.compile` → `execute_b`.
//! Every executable's inputs are `weights... , runtime inputs...` in the
//! manifest's declared order; weights are uploaded once per variant and
//! shared across its executables where names coincide.

use std::collections::BTreeMap;
use std::path::Path;
use std::sync::Arc;

use anyhow::{anyhow, bail, Result};

use crate::config::{Manifest, VariantSpec};

use super::weights::{xla_element_type, WeightFile};

pub struct Engine {
    pub client: xla::PjRtClient,
}

impl Engine {
    pub fn cpu() -> Result<Engine> {
        let client = xla::PjRtClient::cpu()
            .map_err(|e| anyhow!("PJRT cpu client: {e}"))?;
        Ok(Engine { client })
    }

    pub fn platform(&self) -> String {
        self.client.platform_name()
    }

    fn compile(&self, path: &Path) -> Result<xla::PjRtLoadedExecutable> {
        let proto = xla::HloModuleProto::from_text_file(
            path.to_str().ok_or_else(|| anyhow!("non-utf8 path"))?,
        )
        .map_err(|e| anyhow!("parsing HLO {}: {e}", path.display()))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        self.client
            .compile(&comp)
            .map_err(|e| anyhow!("compiling {}: {e}", path.display()))
    }

    /// Upload a host f32 array as a device buffer.
    pub fn upload_f32(&self, data: &[f32], dims: &[usize]) -> Result<xla::PjRtBuffer> {
        self.client
            .buffer_from_host_buffer(data, dims, None)
            .map_err(|e| anyhow!("upload f32: {e}"))
    }

    /// Upload a host i32 array as a device buffer.
    pub fn upload_i32(&self, data: &[i32], dims: &[usize]) -> Result<xla::PjRtBuffer> {
        self.client
            .buffer_from_host_buffer(data, dims, None)
            .map_err(|e| anyhow!("upload i32: {e}"))
    }

    /// Upload an i32 scalar.
    pub fn upload_i32_scalar(&self, v: i32) -> Result<xla::PjRtBuffer> {
        self.client
            .buffer_from_host_buffer(&[v], &[], None)
            .map_err(|e| anyhow!("upload scalar: {e}"))
    }

    /// Zero-filled f32 literal (host side). Upload with
    /// [`Engine::upload_literal`]; the literal must outlive the buffer's
    /// first use because the host->device copy is asynchronous.
    pub fn zeros_literal(&self, dims: &[usize]) -> Result<xla::Literal> {
        let n: usize = dims.iter().product();
        let bytes = vec![0u8; n * 4];
        xla::Literal::create_from_shape_and_untyped_data(xla::ElementType::F32, dims, &bytes)
            .map_err(|e| anyhow!("zeros literal: {e}"))
    }

    pub fn upload_literal(&self, lit: &xla::Literal) -> Result<xla::PjRtBuffer> {
        self.client
            .buffer_from_host_literal(None, lit)
            .map_err(|e| anyhow!("upload literal: {e}"))
    }

    /// Load one variant: weights → device, executables → compiled.
    /// `execs` limits which executables to compile (None = all).
    pub fn load_variant(
        &self,
        manifest: &Manifest,
        name: &str,
        execs: Option<&[&str]>,
    ) -> Result<Variant> {
        let spec = manifest.variant(name)?.clone();
        let wf = WeightFile::load(&manifest.dir, &spec)?;
        // Upload each named weight once.
        let mut weight_bufs: BTreeMap<String, Arc<xla::PjRtBuffer>> = BTreeMap::new();
        let mut weight_literals = Vec::new();
        for p in &spec.params {
            // NOTE: go through a Literal rather than
            // `buffer_from_host_raw_bytes` — the latter passes the
            // ElementType *ordinal* where the C API expects a
            // PrimitiveType, silently mislabeling F32 data as F16.
            // The upload is ASYNC and captures the literal's pointer, so
            // the literal must stay alive as long as the variant.
            let lit = xla::Literal::create_from_shape_and_untyped_data(
                xla_element_type(p.dtype),
                &p.shape,
                wf.bytes(p),
            )
            .map_err(|e| anyhow!("literal for weight {}: {e}", p.name))?;
            let buf = self
                .client
                .buffer_from_host_literal(None, &lit)
                .map_err(|e| anyhow!("upload weight {}: {e}", p.name))?;
            weight_bufs.insert(p.name.clone(), Arc::new(buf));
            weight_literals.push(lit);
        }
        let mut loaded = BTreeMap::new();
        for (tag, espec) in &spec.executables {
            if let Some(filter) = execs {
                if !filter.contains(&tag.as_str()) {
                    continue;
                }
            }
            let exe = self.compile(&manifest.dir.join(&espec.file))?;
            let weights = espec
                .weight_params
                .iter()
                .map(|n| {
                    weight_bufs
                        .get(n)
                        .cloned()
                        .ok_or_else(|| anyhow!("exec {tag} wants unknown weight {n}"))
                })
                .collect::<Result<Vec<_>>>()?;
            loaded.insert(
                tag.clone(),
                LoadedExec {
                    tag: tag.clone(),
                    exe,
                    weights,
                    n_outputs: espec.outputs.len(),
                },
            );
        }
        let kv_zeros = self.zeros_literal(&manifest.kv_shape)?;
        Ok(Variant {
            spec,
            execs: loaded,
            kv_shape: manifest.kv_shape.clone(),
            kv_zeros,
            _weight_literals: weight_literals,
        })
    }
}

/// One compiled executable plus its device-resident weight inputs.
pub struct LoadedExec {
    pub tag: String,
    exe: xla::PjRtLoadedExecutable,
    weights: Vec<Arc<xla::PjRtBuffer>>,
    n_outputs: usize,
}

impl LoadedExec {
    /// Execute with the given runtime inputs appended after the weights.
    pub fn run(&self, runtime_inputs: &[&xla::PjRtBuffer]) -> Result<Vec<xla::PjRtBuffer>> {
        let mut args: Vec<&xla::PjRtBuffer> =
            self.weights.iter().map(|a| a.as_ref()).collect();
        args.extend_from_slice(runtime_inputs);
        let mut out = self
            .exe
            .execute_b(&args)
            .map_err(|e| anyhow!("executing {}: {e}", self.tag))?;
        if out.is_empty() {
            bail!("executing {}: no replica outputs", self.tag);
        }
        let outputs = out.swap_remove(0);
        if outputs.len() != self.n_outputs {
            bail!(
                "executing {}: expected {} outputs, got {}",
                self.tag,
                self.n_outputs,
                outputs.len()
            );
        }
        Ok(outputs)
    }
}

/// A fully-loaded model variant: decode step, prefill buckets, and the
/// FFN micro-executables for breakdown benches.
pub struct Variant {
    pub spec: VariantSpec,
    pub execs: BTreeMap<String, LoadedExec>,
    pub kv_shape: Vec<usize>,
    /// Cached zero KV literal: `fresh_kv` re-uploads it; it must outlive
    /// the async host->device copies it feeds.
    kv_zeros: xla::Literal,
    /// Host mirrors of the uploaded weights; the async host->device copy
    /// holds raw pointers into these, so they live as long as the variant.
    _weight_literals: Vec<xla::Literal>,
}

impl Variant {
    pub fn exec(&self, tag: &str) -> Result<&LoadedExec> {
        self.execs.get(tag).ok_or_else(|| {
            anyhow!(
                "variant {} has no executable {tag:?} loaded (have: {})",
                self.spec.name,
                self.execs.keys().cloned().collect::<Vec<_>>().join(", ")
            )
        })
    }

    pub fn fresh_kv(&self, engine: &Engine) -> Result<xla::PjRtBuffer> {
        engine.upload_literal(&self.kv_zeros)
    }

    /// Batched decode: one token per slot. Returns (logits [B*V], kv').
    pub fn decode(
        &self,
        engine: &Engine,
        tokens: &[i32],
        pos: &[i32],
        kv: &xla::PjRtBuffer,
    ) -> Result<(Vec<f32>, xla::PjRtBuffer)> {
        let exec = self.exec("decode")?;
        let t = engine.upload_i32(tokens, &[tokens.len()])?;
        let p = engine.upload_i32(pos, &[pos.len()])?;
        let mut out = exec.run(&[&t, &p, kv])?;
        let kv_new = out.pop().ok_or_else(|| anyhow!("missing kv output"))?;
        let logits_buf = out.pop().ok_or_else(|| anyhow!("missing logits"))?;
        let logits = buffer_to_f32(&logits_buf)?;
        Ok((logits, kv_new))
    }

    /// Prefill one slot with a token chunk using bucket `bucket`.
    /// `tokens` is padded to the bucket length by the caller.
    pub fn prefill(
        &self,
        engine: &Engine,
        bucket: usize,
        tokens: &[i32],
        kv: &xla::PjRtBuffer,
        slot: i32,
        pos0: i32,
    ) -> Result<(Vec<f32>, xla::PjRtBuffer)> {
        if tokens.len() != bucket {
            bail!("prefill bucket {bucket} got {} tokens", tokens.len());
        }
        let exec = self.exec(&format!("prefill{bucket}"))?;
        let t = engine.upload_i32(tokens, &[bucket])?;
        let s = engine.upload_i32_scalar(slot)?;
        let p0 = engine.upload_i32_scalar(pos0)?;
        let mut out = exec.run(&[&t, kv, &s, &p0])?;
        let kv_new = out.pop().ok_or_else(|| anyhow!("missing kv output"))?;
        let logits_buf = out.pop().ok_or_else(|| anyhow!("missing logits"))?;
        let logits = buffer_to_f32(&logits_buf)?;
        Ok((logits, kv_new))
    }
}

/// Copy a device buffer's f32 contents to the host.
pub fn buffer_to_f32(buf: &xla::PjRtBuffer) -> Result<Vec<f32>> {
    let lit = buf
        .to_literal_sync()
        .map_err(|e| anyhow!("buffer to literal: {e}"))?;
    lit.to_vec::<f32>().map_err(|e| anyhow!("literal to vec: {e}"))
}

/// Copy a device buffer's i32 contents to the host.
pub fn buffer_to_i32(buf: &xla::PjRtBuffer) -> Result<Vec<i32>> {
    let lit = buf
        .to_literal_sync()
        .map_err(|e| anyhow!("buffer to literal: {e}"))?;
    lit.to_vec::<i32>().map_err(|e| anyhow!("literal to vec: {e}"))
}
