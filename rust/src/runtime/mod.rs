//! The model runtime layer.
//!
//! * [`weights`] — std-only weight handling: the `<variant>.weights.bin`
//!   reader addressed by the manifest's parameter table, plus the native
//!   backend's [`weights::NativeWeights`] (seeded synthesis or file
//!   load).
//! * [`engine`]  — the PJRT engine (behind the `pjrt` cargo feature):
//!   loads AOT artifacts (HLO text + weights) and executes them with
//!   device-resident state. Python never runs here.

pub mod weights;

#[cfg(feature = "pjrt")]
pub mod engine;

#[cfg(feature = "pjrt")]
pub use engine::{Engine, LoadedExec, Variant};
