//! The PJRT runtime: loads AOT artifacts (HLO text + weights) and executes
//! them with device-resident state. Python never runs here.

pub mod engine;
pub mod weights;

pub use engine::{Engine, LoadedExec, Variant};
