//! Weight init + loading (std-only).
//!
//! [`WeightFile`]: the `<variant>.weights.bin` reader — raw little-endian
//! arrays addressed by the manifest's parameter table (uploaded once as
//! device buffers under the `pjrt` feature).
//!
//! [`NativeWeights`]: the native backend's full parameter set — either
//! synthesized deterministically from a seed (no artifacts required; the
//! default for every native CLI path) or loaded from a [`WeightFile`]
//! whose parameter table follows the native naming convention
//! (`embed`, `layers.<i>.ln1.g`, `layers.<i>.attn.wq`, `layers.<i>.w1`,
//! …, `lnf.g`).

use std::path::Path;
use std::sync::Arc;

use anyhow::{anyhow, bail, Context, Result};

use crate::config::{DType, NativeModelConfig, ParamEntry, VariantSpec};
use crate::ffn::kernels::PackedMatrix;
use crate::util::rng::Rng;

/// The raw weight blob for one variant.
pub struct WeightFile {
    data: Vec<u8>,
}

impl WeightFile {
    pub fn load(dir: &Path, variant: &VariantSpec) -> Result<WeightFile> {
        let path = dir.join(&variant.weights_file);
        let data = std::fs::read(&path)
            .with_context(|| format!("reading {}", path.display()))?;
        // Validate the table against the blob before anything touches it.
        for p in &variant.params {
            let elems: usize = p.shape.iter().product();
            if elems * p.dtype.size() != p.nbytes {
                bail!(
                    "param {} table inconsistent: shape {:?} x {}B != {}B",
                    p.name,
                    p.shape,
                    p.dtype.size(),
                    p.nbytes
                );
            }
            if p.offset + p.nbytes > data.len() {
                bail!(
                    "param {} overruns weight file ({} + {} > {})",
                    p.name,
                    p.offset,
                    p.nbytes,
                    data.len()
                );
            }
        }
        Ok(WeightFile { data })
    }

    pub fn bytes(&self, p: &ParamEntry) -> &[u8] {
        &self.data[p.offset..p.offset + p.nbytes]
    }

    pub fn f32_slice(&self, p: &ParamEntry) -> Result<Vec<f32>> {
        if p.dtype != DType::F32 {
            bail!("param {} is not f32", p.name);
        }
        let b = self.bytes(p);
        Ok(b.chunks_exact(4)
            .map(|c| f32::from_le_bytes([c[0], c[1], c[2], c[3]]))
            .collect())
    }

    pub fn i8_slice(&self, p: &ParamEntry) -> Result<Vec<i8>> {
        if p.dtype != DType::I8 {
            bail!("param {} is not i8", p.name);
        }
        Ok(self.bytes(p).iter().map(|&b| b as i8).collect())
    }

    pub fn len(&self) -> usize {
        self.data.len()
    }

    pub fn is_empty(&self) -> bool {
        self.data.is_empty()
    }
}

#[cfg(feature = "pjrt")]
pub fn xla_element_type(dt: DType) -> xla::ElementType {
    match dt {
        DType::F32 => xla::ElementType::F32,
        DType::I32 => xla::ElementType::S32,
        DType::I8 => xla::ElementType::S8,
    }
}

// ---------------------------------------------------------------------------
// Native backend weights.
// ---------------------------------------------------------------------------

/// Attention projections of one layer, each `[d_model, d_model]`
/// row-major (input × output), bias-free. Only the packed forms are
/// kept resident — nothing reads the raw layout after load, so storing
/// it too would double the attention weight footprint.
pub struct AttnWeights {
    pub wq_packed: PackedMatrix,
    pub wk_packed: PackedMatrix,
    pub wv_packed: PackedMatrix,
    pub wo_packed: PackedMatrix,
}

impl AttnWeights {
    /// Pack the four projections at construction; the row-major inputs
    /// are dropped.
    pub fn new(wq: &[f32], wk: &[f32], wv: &[f32], wo: &[f32], d: usize) -> AttnWeights {
        AttnWeights {
            wq_packed: PackedMatrix::pack(wq, d, d),
            wk_packed: PackedMatrix::pack(wk, d, d),
            wv_packed: PackedMatrix::pack(wv, d, d),
            wo_packed: PackedMatrix::pack(wo, d, d),
        }
    }
}

/// Per-layer TARDIS calibration exported by the python compile pipeline
/// (`python/compile/native_export.py`): per-neuron linear ranges + fits
/// from Algorithm 1, and the k-bit quantized `W1` proxy. Optional — a
/// manifest without the `layers.<i>.tardis.*` params loads with `None`
/// and the native backend falls back to the uniform configured range.
#[derive(Debug, Clone, PartialEq)]
pub struct LayerCalib {
    /// `[d_ff]` per-neuron range bounds, `lo[j] <= z < hi[j]`.
    pub lo: Vec<f32>,
    pub hi: Vec<f32>,
    /// `[d_ff]` per-neuron least-squares fit `a·z + b` on the range.
    pub lin_a: Vec<f32>,
    pub lin_b: Vec<f32>,
    /// `[d_model, d_ff]` row-major i8 codes of the quantized `W1` copy.
    pub pred_codes: Vec<i8>,
    /// `[d_model / group, d_ff]` row-major per-(group, neuron) scales.
    pub pred_scales: Vec<f32>,
    /// Reduction-group size implied by the scales shape.
    pub group: usize,
}

/// One pre-LN transformer block's parameters.
pub struct LayerWeights {
    pub ln1_gain: Vec<f32>,
    pub ln1_bias: Vec<f32>,
    pub attn: AttnWeights,
    pub ln2_gain: Vec<f32>,
    pub ln2_bias: Vec<f32>,
    /// `[d_model, d_ff]` row-major.
    pub w1: Arc<Vec<f32>>,
    /// `[d_ff]`.
    pub b1: Arc<Vec<f32>>,
    /// `[d_ff, d_model]` row-major.
    pub w2: Arc<Vec<f32>>,
    /// `[d_model]`.
    pub b2: Arc<Vec<f32>>,
    /// Per-neuron calibrated ranges + quantized predictor, when the
    /// manifest ships them.
    pub calib: Option<LayerCalib>,
}

/// Full parameter set of the native tiny-GELU transformer (tied
/// input/output embedding).
pub struct NativeWeights {
    /// `[vocab, d_model]` row-major.
    pub embed: Arc<Vec<f32>>,
    /// The tied embedding transposed to `[d_model, vocab]` and packed,
    /// so the unembedding runs the blocked GEMM instead of per-token
    /// dot products.
    pub unembed_packed: PackedMatrix,
    pub layers: Vec<LayerWeights>,
    pub lnf_gain: Vec<f32>,
    pub lnf_bias: Vec<f32>,
}

fn normal_vec(rng: &mut Rng, n: usize, scale: f64) -> Vec<f32> {
    (0..n).map(|_| (rng.normal() * scale) as f32).collect()
}

/// `logits = x · Eᵀ`: transpose the tied embedding once and pack it.
fn pack_unembed(embed: &[f32], vocab: usize, d: usize) -> PackedMatrix {
    let mut t = vec![0f32; d * vocab];
    for (token, erow) in embed.chunks_exact(d).enumerate().take(vocab) {
        for (l, &v) in erow.iter().enumerate() {
            t[l * vocab + token] = v;
        }
    }
    PackedMatrix::pack(&t, d, vocab)
}

impl NativeWeights {
    /// Deterministic seeded init (GPT-2-style scales: `1/√d` fan-in,
    /// residual projections damped so the stream stays stable).
    pub fn synthesize(cfg: &NativeModelConfig) -> NativeWeights {
        let (d, h, v) = (cfg.d_model, cfg.d_ff, cfg.vocab);
        let mut rng = Rng::new(cfg.seed);
        let proj = 1.0 / (d as f64).sqrt();
        let resid = proj * 0.5;
        let embed = Arc::new(normal_vec(&mut rng, v * d, 0.3));
        let layers = (0..cfg.n_layers)
            .map(|_| LayerWeights {
                ln1_gain: vec![1.0; d],
                ln1_bias: vec![0.0; d],
                attn: AttnWeights::new(
                    &normal_vec(&mut rng, d * d, proj),
                    &normal_vec(&mut rng, d * d, proj),
                    &normal_vec(&mut rng, d * d, proj),
                    &normal_vec(&mut rng, d * d, resid),
                    d,
                ),
                ln2_gain: vec![1.0; d],
                ln2_bias: vec![0.0; d],
                w1: Arc::new(normal_vec(&mut rng, d * h, proj)),
                b1: Arc::new(vec![0.0; h]),
                w2: Arc::new(normal_vec(&mut rng, h * d, 0.5 / (h as f64).sqrt())),
                b2: Arc::new(vec![0.0; d]),
                calib: None,
            })
            .collect();
        NativeWeights {
            unembed_packed: pack_unembed(&embed, v, d),
            embed,
            layers,
            lnf_gain: vec![1.0; d],
            lnf_bias: vec![0.0; d],
        }
    }

    /// Load from a manifest-addressed weight blob using the native
    /// parameter naming convention. Every parameter must be present,
    /// f32, and of the exact shape the config implies.
    pub fn from_weight_file(
        wf: &WeightFile,
        variant: &VariantSpec,
        cfg: &NativeModelConfig,
    ) -> Result<NativeWeights> {
        let (d, h, v) = (cfg.d_model, cfg.d_ff, cfg.vocab);
        let get = |name: &str, shape: &[usize]| -> Result<Vec<f32>> {
            let p = variant.param(name)?;
            if p.shape != shape {
                bail!(
                    "param {name}: manifest shape {:?} != expected {shape:?}",
                    p.shape
                );
            }
            wf.f32_slice(p)
        };
        let embed = Arc::new(get("embed", &[v, d])?);
        let mut layers = Vec::with_capacity(cfg.n_layers);
        for i in 0..cfg.n_layers {
            let n = |suffix: &str| format!("layers.{i}.{suffix}");
            // Optional per-layer calibration: all-or-nothing — a manifest
            // shipping `tardis.lo` must ship the full set.
            let calib = if variant.param(&n("tardis.lo")).is_ok() {
                let codes_p = variant.param(&n("tardis.pred_codes"))?;
                if codes_p.shape != [d, h] {
                    bail!(
                        "param {}: manifest shape {:?} != expected {:?}",
                        n("tardis.pred_codes"),
                        codes_p.shape,
                        [d, h]
                    );
                }
                let scales_p = variant.param(&n("tardis.pred_scales"))?;
                // The group size is authoritative in the variant's
                // `predictor_group`; the scales shape must agree with it
                // (short tail groups allowed). Without a tardis config
                // (e.g. a dense variant sharing the blob) fall back to
                // inferring an exactly-dividing group from the shape.
                let group = match &variant.tardis {
                    Some(t) => {
                        let g = t.predictor_group.max(1);
                        let rows = d.div_ceil(g);
                        if scales_p.shape != [rows, h] {
                            bail!(
                                "param {}: scales shape {:?} != {:?} implied \
                                 by predictor_group {g}",
                                n("tardis.pred_scales"),
                                scales_p.shape,
                                [rows, h]
                            );
                        }
                        g
                    }
                    None => match scales_p.shape.as_slice() {
                        [rows, hh] if *hh == h && *rows >= 1 && d % *rows == 0 => {
                            d / *rows
                        }
                        other => bail!(
                            "param {}: scales shape {other:?} does not tile \
                             d_model {d} over d_ff {h}",
                            n("tardis.pred_scales")
                        ),
                    },
                };
                Some(LayerCalib {
                    lo: get(&n("tardis.lo"), &[h])?,
                    hi: get(&n("tardis.hi"), &[h])?,
                    lin_a: get(&n("tardis.lin_a"), &[h])?,
                    lin_b: get(&n("tardis.lin_b"), &[h])?,
                    pred_codes: wf.i8_slice(codes_p)?,
                    pred_scales: wf.f32_slice(scales_p)?,
                    group,
                })
            } else {
                None
            };
            layers.push(LayerWeights {
                ln1_gain: get(&n("ln1.g"), &[d])?,
                ln1_bias: get(&n("ln1.b"), &[d])?,
                attn: AttnWeights::new(
                    &get(&n("attn.wq"), &[d, d])?,
                    &get(&n("attn.wk"), &[d, d])?,
                    &get(&n("attn.wv"), &[d, d])?,
                    &get(&n("attn.wo"), &[d, d])?,
                    d,
                ),
                ln2_gain: get(&n("ln2.g"), &[d])?,
                ln2_bias: get(&n("ln2.b"), &[d])?,
                w1: Arc::new(get(&n("w1"), &[d, h])?),
                b1: Arc::new(get(&n("b1"), &[h])?),
                w2: Arc::new(get(&n("w2"), &[h, d])?),
                b2: Arc::new(get(&n("b2"), &[d])?),
                calib,
            });
        }
        Ok(NativeWeights {
            unembed_packed: pack_unembed(&embed, v, d),
            embed,
            layers,
            lnf_gain: get("lnf.g", &[d])?,
            lnf_bias: get("lnf.b", &[d])?,
        })
    }

    /// Load `<dir>/<variant>.weights.bin` per the variant's table.
    pub fn load(
        dir: &Path,
        variant: &VariantSpec,
        cfg: &NativeModelConfig,
    ) -> Result<NativeWeights> {
        let wf = WeightFile::load(dir, variant)
            .map_err(|e| anyhow!("native weights for {}: {e}", variant.name))?;
        NativeWeights::from_weight_file(&wf, variant, cfg)
    }

    pub fn param_count(&self, cfg: &NativeModelConfig) -> usize {
        let (d, h) = (cfg.d_model, cfg.d_ff);
        cfg.vocab * d
            + cfg.n_layers * (4 * d + 4 * d * d + 2 * d * h + h + d)
            + 2 * d
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::{DType, ExecSpec, ParamEntry, VariantSpec};
    use std::collections::BTreeMap;

    fn spec(params: Vec<ParamEntry>) -> VariantSpec {
        VariantSpec {
            name: "t".into(),
            ffn_mode: "dense".into(),
            fix_capacity: 0,
            compression_ratio: 0.0,
            weights_file: "t.weights.bin".into(),
            params,
            executables: BTreeMap::<String, ExecSpec>::new(),
            tardis: None,
        }
    }

    #[test]
    fn reads_f32_params() {
        let dir = std::env::temp_dir().join("tardis_weights_test");
        std::fs::create_dir_all(&dir).unwrap();
        let vals: Vec<f32> = vec![1.0, -2.5, 3.25, 0.0];
        let bytes: Vec<u8> = vals.iter().flat_map(|v| v.to_le_bytes()).collect();
        std::fs::write(dir.join("t.weights.bin"), &bytes).unwrap();
        let v = spec(vec![ParamEntry {
            name: "w".into(),
            dtype: DType::F32,
            shape: vec![2, 2],
            offset: 0,
            nbytes: 16,
        }]);
        let wf = WeightFile::load(&dir, &v).unwrap();
        assert_eq!(wf.f32_slice(&v.params[0]).unwrap(), vals);
    }

    #[test]
    fn rejects_inconsistent_table() {
        let dir = std::env::temp_dir().join("tardis_weights_test2");
        std::fs::create_dir_all(&dir).unwrap();
        std::fs::write(dir.join("t.weights.bin"), [0u8; 8]).unwrap();
        // shape says 4 f32 = 16 bytes but nbytes says 8
        let v = spec(vec![ParamEntry {
            name: "w".into(),
            dtype: DType::F32,
            shape: vec![4],
            offset: 0,
            nbytes: 8,
        }]);
        assert!(WeightFile::load(&dir, &v).is_err());
    }

    fn tiny_cfg() -> NativeModelConfig {
        NativeModelConfig {
            vocab: 8,
            d_model: 4,
            n_layers: 1,
            n_heads: 2,
            d_ff: 8,
            max_seq: 16,
            batch: 2,
            prefill_buckets: vec![4],
            seed: 99,
            threads: 0,
            kv_block_size: 4,
            kv_blocks: 0,
        }
    }

    #[test]
    fn synthesize_is_deterministic_and_shaped() {
        let cfg = tiny_cfg();
        let a = NativeWeights::synthesize(&cfg);
        let b = NativeWeights::synthesize(&cfg);
        assert_eq!(a.embed.len(), cfg.vocab * cfg.d_model);
        assert_eq!(a.layers.len(), 1);
        assert_eq!(a.layers[0].w1.len(), cfg.d_model * cfg.d_ff);
        assert_eq!(a.layers[0].w2.len(), cfg.d_ff * cfg.d_model);
        assert_eq!(*a.embed, *b.embed, "same seed => same weights");
        assert_eq!(
            a.layers[0].attn.wq_packed.panel(0),
            b.layers[0].attn.wq_packed.panel(0)
        );
        assert_eq!(*a.layers[0].w2, *b.layers[0].w2);
        // the packed unembedding is the transposed tied embedding
        assert_eq!(a.unembed_packed.k(), cfg.d_model);
        assert_eq!(a.unembed_packed.m(), cfg.vocab);
        assert_eq!(a.unembed_packed.panel(0)[1], a.embed[cfg.d_model]);
        assert_eq!(a.layers[0].attn.wq_packed.k(), cfg.d_model);
        let other = NativeWeights::synthesize(&NativeModelConfig {
            seed: 100,
            ..cfg
        });
        assert_ne!(*a.embed, *other.embed, "seed changes weights");
    }

    #[test]
    fn from_weight_file_roundtrips_native_params() {
        let cfg = tiny_cfg();
        let (d, h, v) = (cfg.d_model, cfg.d_ff, cfg.vocab);
        // Build the blob + table for the native naming convention.
        let names: Vec<(String, Vec<usize>)> = {
            let mut ns = vec![("embed".to_string(), vec![v, d])];
            let l = |s: &str| format!("layers.0.{s}");
            for (s, shape) in [
                ("ln1.g", vec![d]),
                ("ln1.b", vec![d]),
                ("attn.wq", vec![d, d]),
                ("attn.wk", vec![d, d]),
                ("attn.wv", vec![d, d]),
                ("attn.wo", vec![d, d]),
                ("ln2.g", vec![d]),
                ("ln2.b", vec![d]),
                ("w1", vec![d, h]),
                ("b1", vec![h]),
                ("w2", vec![h, d]),
                ("b2", vec![d]),
            ] {
                ns.push((l(s), shape));
            }
            ns.push(("lnf.g".to_string(), vec![d]));
            ns.push(("lnf.b".to_string(), vec![d]));
            ns
        };
        let mut params = Vec::new();
        let mut blob: Vec<u8> = Vec::new();
        for (name, shape) in &names {
            let elems: usize = shape.iter().product();
            let offset = blob.len();
            for e in 0..elems {
                blob.extend_from_slice(&((offset + e) as f32 * 0.5).to_le_bytes());
            }
            params.push(ParamEntry {
                name: name.clone(),
                dtype: DType::F32,
                shape: shape.clone(),
                offset,
                nbytes: elems * 4,
            });
        }
        let dir = std::env::temp_dir().join("tardis_native_weights_test");
        std::fs::create_dir_all(&dir).unwrap();
        std::fs::write(dir.join("t.weights.bin"), &blob).unwrap();
        let vspec = spec(params);
        let w = NativeWeights::load(&dir, &vspec, &cfg).unwrap();
        assert_eq!(w.embed.len(), v * d);
        assert_eq!(w.embed[0], 0.0);
        assert_eq!(w.embed[1], 0.5);
        assert_eq!(w.layers[0].b1.len(), h);
        assert_eq!(w.lnf_bias.len(), d);
        // wrong shape in the table is rejected
        let mut bad = vspec.clone();
        bad.params[0].shape = vec![d, v];
        assert!(NativeWeights::from_weight_file(
            &WeightFile::load(&dir, &bad).unwrap(),
            &bad,
            &cfg
        )
        .is_err());
    }

    #[test]
    fn rejects_overrun() {
        let dir = std::env::temp_dir().join("tardis_weights_test3");
        std::fs::create_dir_all(&dir).unwrap();
        std::fs::write(dir.join("t.weights.bin"), [0u8; 8]).unwrap();
        let v = spec(vec![ParamEntry {
            name: "w".into(),
            dtype: DType::F32,
            shape: vec![4],
            offset: 4,
            nbytes: 16,
        }]);
        assert!(WeightFile::load(&dir, &v).is_err());
    }
}
