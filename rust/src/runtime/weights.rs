//! `<variant>.weights.bin` reader: raw little-endian arrays addressed by
//! the manifest's parameter table, uploaded once as device buffers.

use std::path::Path;

use anyhow::{bail, Context, Result};

use crate::config::{DType, ParamEntry, VariantSpec};

/// The raw weight blob for one variant.
pub struct WeightFile {
    data: Vec<u8>,
}

impl WeightFile {
    pub fn load(dir: &Path, variant: &VariantSpec) -> Result<WeightFile> {
        let path = dir.join(&variant.weights_file);
        let data = std::fs::read(&path)
            .with_context(|| format!("reading {}", path.display()))?;
        // Validate the table against the blob before anything touches it.
        for p in &variant.params {
            let elems: usize = p.shape.iter().product();
            if elems * p.dtype.size() != p.nbytes {
                bail!("param {} table inconsistent: shape {:?} x {}B != {}B",
                      p.name, p.shape, p.dtype.size(), p.nbytes);
            }
            if p.offset + p.nbytes > data.len() {
                bail!("param {} overruns weight file ({} + {} > {})",
                      p.name, p.offset, p.nbytes, data.len());
            }
        }
        Ok(WeightFile { data })
    }

    pub fn bytes(&self, p: &ParamEntry) -> &[u8] {
        &self.data[p.offset..p.offset + p.nbytes]
    }

    pub fn f32_slice(&self, p: &ParamEntry) -> Result<Vec<f32>> {
        if p.dtype != DType::F32 {
            bail!("param {} is not f32", p.name);
        }
        let b = self.bytes(p);
        Ok(b.chunks_exact(4)
            .map(|c| f32::from_le_bytes([c[0], c[1], c[2], c[3]]))
            .collect())
    }

    pub fn len(&self) -> usize {
        self.data.len()
    }

    pub fn is_empty(&self) -> bool {
        self.data.is_empty()
    }
}

pub fn xla_element_type(dt: DType) -> xla::ElementType {
    match dt {
        DType::F32 => xla::ElementType::F32,
        DType::I32 => xla::ElementType::S32,
        DType::I8 => xla::ElementType::S8,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::{DType, ExecSpec, ParamEntry, VariantSpec};
    use std::collections::BTreeMap;

    fn spec(params: Vec<ParamEntry>) -> VariantSpec {
        VariantSpec {
            name: "t".into(),
            ffn_mode: "dense".into(),
            fix_capacity: 0,
            compression_ratio: 0.0,
            weights_file: "t.weights.bin".into(),
            params,
            executables: BTreeMap::<String, ExecSpec>::new(),
        }
    }

    #[test]
    fn reads_f32_params() {
        let dir = std::env::temp_dir().join("tardis_weights_test");
        std::fs::create_dir_all(&dir).unwrap();
        let vals: Vec<f32> = vec![1.0, -2.5, 3.25, 0.0];
        let bytes: Vec<u8> =
            vals.iter().flat_map(|v| v.to_le_bytes()).collect();
        std::fs::write(dir.join("t.weights.bin"), &bytes).unwrap();
        let v = spec(vec![ParamEntry {
            name: "w".into(),
            dtype: DType::F32,
            shape: vec![2, 2],
            offset: 0,
            nbytes: 16,
        }]);
        let wf = WeightFile::load(&dir, &v).unwrap();
        assert_eq!(wf.f32_slice(&v.params[0]).unwrap(), vals);
    }

    #[test]
    fn rejects_inconsistent_table() {
        let dir = std::env::temp_dir().join("tardis_weights_test2");
        std::fs::create_dir_all(&dir).unwrap();
        std::fs::write(dir.join("t.weights.bin"), [0u8; 8]).unwrap();
        // shape says 4 f32 = 16 bytes but nbytes says 8
        let v = spec(vec![ParamEntry {
            name: "w".into(),
            dtype: DType::F32,
            shape: vec![4],
            offset: 0,
            nbytes: 8,
        }]);
        assert!(WeightFile::load(&dir, &v).is_err());
    }

    #[test]
    fn rejects_overrun() {
        let dir = std::env::temp_dir().join("tardis_weights_test3");
        std::fs::create_dir_all(&dir).unwrap();
        std::fs::write(dir.join("t.weights.bin"), [0u8; 8]).unwrap();
        let v = spec(vec![ParamEntry {
            name: "w".into(),
            dtype: DType::F32,
            shape: vec![4],
            offset: 4,
            nbytes: 16,
        }]);
        assert!(WeightFile::load(&dir, &v).is_err());
    }
}
