//! Line-delimited-JSON TCP protocol + server plumbing.
//!
//! Request:  `{"op":"generate","prompt":"text","max_tokens":32,
//!             "temperature":0.0,"variant":"tardis80"}`
//! Response: `{"ok":true,"id":1,"text":"...","tokens":[...],
//!             "reason":"length","total_ms":12.3}`
//! Also: `{"op":"stats"}`, `{"op":"ping"}`.
//!
//! The server thread owns the engine (the PJRT buffers are not Sync);
//! connection handlers forward requests over channels. Token encoding is
//! byte-level (vocab 256), matching the python corpus module.

pub mod protocol;
pub mod tcp;

pub use protocol::{parse_request, render_completion, render_error, ServerRequest};
