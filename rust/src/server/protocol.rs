//! Wire protocol: one JSON object per line, both directions.

use anyhow::{anyhow, Result};

use crate::coordinator::engine_loop::Completion;
use crate::coordinator::request::{FinishReason, SamplingParams};
use crate::util::json::Json;

#[derive(Debug, Clone, PartialEq)]
pub enum ServerRequest {
    Generate {
        prompt: Vec<i32>,
        params: SamplingParams,
        variant: Option<String>,
    },
    Stats,
    Ping,
}

/// Byte-level tokenization (vocab = 256), mirroring python corpus.encode.
pub fn encode_text(s: &str) -> Vec<i32> {
    s.as_bytes().iter().map(|&b| b as i32).collect()
}

pub fn decode_tokens(tokens: &[i32]) -> String {
    let bytes: Vec<u8> = tokens.iter().map(|&t| (t & 0xFF) as u8).collect();
    String::from_utf8_lossy(&bytes).into_owned()
}

pub fn parse_request(line: &str) -> Result<ServerRequest> {
    let j = Json::parse(line.trim()).map_err(|e| anyhow!("bad json: {e}"))?;
    let op = j
        .get("op")
        .and_then(Json::as_str)
        .ok_or_else(|| anyhow!("missing \"op\""))?;
    match op {
        "ping" => Ok(ServerRequest::Ping),
        "stats" => Ok(ServerRequest::Stats),
        "generate" => {
            let prompt = match (j.get("prompt").and_then(Json::as_str),
                                j.get("prompt_tokens").and_then(Json::as_arr)) {
                (Some(text), _) => encode_text(text),
                (None, Some(arr)) => arr
                    .iter()
                    .map(|v| {
                        v.as_i64()
                            .map(|x| x as i32)
                            .ok_or_else(|| anyhow!("non-integer token"))
                    })
                    .collect::<Result<Vec<_>>>()?,
                _ => return Err(anyhow!("generate needs prompt or prompt_tokens")),
            };
            if prompt.is_empty() {
                return Err(anyhow!("empty prompt"));
            }
            let params = SamplingParams {
                temperature: j
                    .get("temperature")
                    .and_then(Json::as_f64)
                    .unwrap_or(0.0) as f32,
                top_k: j.get("top_k").and_then(Json::as_usize).unwrap_or(0),
                max_tokens: j
                    .get("max_tokens")
                    .and_then(Json::as_usize)
                    .unwrap_or(32),
                stop_token: j
                    .get("stop_token")
                    .and_then(Json::as_i64)
                    .map(|v| v as i32),
                seed: j.get("seed").and_then(Json::as_i64).unwrap_or(0) as u64,
            };
            let variant = j
                .get("variant")
                .and_then(Json::as_str)
                .map(str::to_string);
            Ok(ServerRequest::Generate { prompt, params, variant })
        }
        other => Err(anyhow!("unknown op {other:?}")),
    }
}

fn reason_str(r: FinishReason) -> &'static str {
    match r {
        FinishReason::Length => "length",
        FinishReason::Stop => "stop",
        FinishReason::ContextOverflow => "context_overflow",
        FinishReason::Cancelled => "cancelled",
    }
}

pub fn render_completion(c: &Completion, variant: &str) -> String {
    Json::obj(vec![
        ("ok", Json::Bool(true)),
        ("id", Json::num(c.id as f64)),
        ("variant", Json::str(variant)),
        ("text", Json::str(&decode_tokens(&c.tokens))),
        ("tokens", Json::arr(c.tokens.iter().map(|&t| Json::num(t as f64)))),
        ("reason", Json::str(reason_str(c.reason))),
        ("first_token_ms", Json::num(c.first_token_ms)),
        ("total_ms", Json::num(c.total_ms)),
    ])
    .render()
}

pub fn render_error(msg: &str) -> String {
    Json::obj(vec![("ok", Json::Bool(false)), ("error", Json::str(msg))]).render()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_generate_text() {
        let r = parse_request(
            r#"{"op":"generate","prompt":"hi","max_tokens":4,"temperature":0.5}"#,
        )
        .unwrap();
        match r {
            ServerRequest::Generate { prompt, params, variant } => {
                assert_eq!(prompt, vec![104, 105]);
                assert_eq!(params.max_tokens, 4);
                assert!((params.temperature - 0.5).abs() < 1e-6);
                assert!(variant.is_none());
            }
            _ => panic!("wrong request"),
        }
    }

    #[test]
    fn parses_generate_tokens_and_variant() {
        let r = parse_request(
            r#"{"op":"generate","prompt_tokens":[1,2,3],"variant":"tardis80"}"#,
        )
        .unwrap();
        match r {
            ServerRequest::Generate { prompt, variant, .. } => {
                assert_eq!(prompt, vec![1, 2, 3]);
                assert_eq!(variant.as_deref(), Some("tardis80"));
            }
            _ => panic!("wrong request"),
        }
    }

    #[test]
    fn rejects_bad_requests() {
        assert!(parse_request("not json").is_err());
        assert!(parse_request(r#"{"op":"nope"}"#).is_err());
        assert!(parse_request(r#"{"op":"generate"}"#).is_err());
        assert!(parse_request(r#"{"op":"generate","prompt":""}"#).is_err());
    }

    #[test]
    fn text_roundtrip() {
        let s = "the falcon folds";
        assert_eq!(decode_tokens(&encode_text(s)), s);
    }

    #[test]
    fn ping_and_stats() {
        assert_eq!(parse_request(r#"{"op":"ping"}"#).unwrap(), ServerRequest::Ping);
        assert_eq!(parse_request(r#"{"op":"stats"}"#).unwrap(), ServerRequest::Stats);
    }
}
