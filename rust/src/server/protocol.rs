//! Wire protocol: one JSON object per line, both directions.

use anyhow::{anyhow, Result};

use crate::coordinator::engine_loop::{Completion, EngineSnapshot};
use crate::coordinator::request::{FinishReason, SamplingParams};
use crate::coordinator::router::FrontSnapshot;
use crate::util::json::Json;

#[derive(Debug, Clone, PartialEq)]
pub enum ServerRequest {
    Generate {
        prompt: Vec<i32>,
        params: SamplingParams,
        variant: Option<String>,
        /// Client retry attempt number (0 = first try); set by the
        /// retry helper when resending after an `overloaded` shed.
        retry: u64,
    },
    Stats,
    Ping,
}

/// Byte-level tokenization (vocab = 256), mirroring python corpus.encode.
pub fn encode_text(s: &str) -> Vec<i32> {
    s.as_bytes().iter().map(|&b| b as i32).collect()
}

pub fn decode_tokens(tokens: &[i32]) -> String {
    let bytes: Vec<u8> = tokens.iter().map(|&t| (t & 0xFF) as u8).collect();
    String::from_utf8_lossy(&bytes).into_owned()
}

pub fn parse_request(line: &str) -> Result<ServerRequest> {
    let j = Json::parse(line.trim()).map_err(|e| anyhow!("bad json: {e}"))?;
    let op = j
        .get("op")
        .and_then(Json::as_str)
        .ok_or_else(|| anyhow!("missing \"op\""))?;
    match op {
        "ping" => Ok(ServerRequest::Ping),
        "stats" => Ok(ServerRequest::Stats),
        "generate" => {
            let prompt = match (j.get("prompt").and_then(Json::as_str),
                                j.get("prompt_tokens").and_then(Json::as_arr)) {
                (Some(text), _) => encode_text(text),
                (None, Some(arr)) => arr
                    .iter()
                    .map(|v| {
                        v.as_i64()
                            .map(|x| x as i32)
                            .ok_or_else(|| anyhow!("non-integer token"))
                    })
                    .collect::<Result<Vec<_>>>()?,
                _ => return Err(anyhow!("generate needs prompt or prompt_tokens")),
            };
            if prompt.is_empty() {
                return Err(anyhow!("empty prompt"));
            }
            let params = SamplingParams {
                temperature: j
                    .get("temperature")
                    .and_then(Json::as_f64)
                    .unwrap_or(0.0) as f32,
                top_k: j.get("top_k").and_then(Json::as_usize).unwrap_or(0),
                max_tokens: j
                    .get("max_tokens")
                    .and_then(Json::as_usize)
                    .unwrap_or(32),
                stop_token: j
                    .get("stop_token")
                    .and_then(Json::as_i64)
                    .map(|v| v as i32),
                seed: j.get("seed").and_then(Json::as_i64).unwrap_or(0) as u64,
                priority: j
                    .get("priority")
                    .and_then(Json::as_i64)
                    .unwrap_or(0) as i32,
                ttft_deadline_ms: j
                    .get("ttft_deadline_ms")
                    .and_then(Json::as_i64)
                    .filter(|&v| v >= 0)
                    .map(|v| v as u64),
                tpot_deadline_ms: j
                    .get("tpot_deadline_ms")
                    .and_then(Json::as_i64)
                    .filter(|&v| v >= 0)
                    .map(|v| v as u64),
                // Never a client decision: only the front door's overload
                // ladder (or the trace harness) may degrade a request.
                degrade: false,
            };
            let variant = j
                .get("variant")
                .and_then(Json::as_str)
                .map(str::to_string);
            let retry = j.get("retry").and_then(Json::as_i64).unwrap_or(0).max(0) as u64;
            Ok(ServerRequest::Generate { prompt, params, variant, retry })
        }
        other => Err(anyhow!("unknown op {other:?}")),
    }
}

fn reason_str(r: FinishReason) -> &'static str {
    r.as_str()
}

pub fn render_completion(c: &Completion, variant: &str) -> String {
    Json::obj(vec![
        ("ok", Json::Bool(true)),
        ("id", Json::num(c.id as f64)),
        ("variant", Json::str(variant)),
        ("text", Json::str(&decode_tokens(&c.tokens))),
        ("tokens", Json::arr(c.tokens.iter().map(|&t| Json::num(t as f64)))),
        ("reason", Json::str(reason_str(c.reason))),
        ("queue_ms", Json::num(c.queue_ms)),
        ("first_token_ms", Json::num(c.first_token_ms)),
        ("total_ms", Json::num(c.total_ms)),
        ("prefix_hit_tokens", Json::num(c.prefix_hit_tokens as f64)),
        ("degraded", Json::Bool(c.degraded)),
    ])
    .render()
}

/// The per-replica engine fields shared by [`render_stats`] and
/// [`render_front_stats`].
fn replica_fields<'a>(name: &'a str, s: &EngineSnapshot) -> Vec<(&'a str, Json)> {
    vec![
        ("variant", Json::str(name)),
        ("policy", Json::str(s.policy)),
        ("queue_depth", Json::num(s.queue_depth as f64)),
        ("queue_pressure", Json::num(s.queue_pressure)),
        ("active_slots", Json::num(s.active_slots as f64)),
        ("inflight_prefills", Json::num(s.inflight_prefills as f64)),
        ("slots_total", Json::num(s.slots_total as f64)),
        ("kv_blocks_total", Json::num(s.kv_blocks_total as f64)),
        ("kv_blocks_used", Json::num(s.kv_blocks_used as f64)),
        ("block_utilization", Json::num(s.block_utilization)),
        ("swapped", Json::num(s.swapped as f64)),
        ("preemptions", Json::num(s.preemptions as f64)),
        ("mixed_step_ratio", s.mixed_step_ratio.map(Json::num).unwrap_or(Json::Null)),
        ("mean_occupancy", Json::num(s.mean_occupancy)),
        ("tokens_generated", Json::num(s.tokens_generated as f64)),
        ("admitted", Json::num(s.admitted as f64)),
        ("finished", Json::num(s.finished as f64)),
        ("iterations", Json::num(s.iterations as f64)),
        (
            "ffn_fallback_rate",
            s.ffn_fallback_rate.map(Json::num).unwrap_or(Json::Null),
        ),
        (
            "ffn_last_step_fallback_rate",
            s.ffn_last_step_fallback_rate.map(Json::num).unwrap_or(Json::Null),
        ),
        ("prefix_cached_blocks", Json::num(s.prefix_cached_blocks as f64)),
        (
            "prefix_evictable_blocks",
            Json::num(s.prefix_evictable_blocks as f64),
        ),
        ("prefix_hit_tokens", Json::num(s.prefix_hit_tokens as f64)),
        ("prefix_shared_blocks", Json::num(s.prefix_shared_blocks as f64)),
        ("cow_copies", Json::num(s.cow_copies as f64)),
        ("prefix_evictions", Json::num(s.prefix_evictions as f64)),
    ]
}

/// Render the `stats` op response: one object per replica with live
/// queue/slot/throughput numbers.
pub fn render_stats(replicas: &[(String, EngineSnapshot)]) -> String {
    Json::obj(vec![
        ("ok", Json::Bool(true)),
        (
            "replicas",
            Json::arr(
                replicas
                    .iter()
                    .map(|(name, s)| Json::obj(replica_fields(name, s))),
            ),
        ),
    ])
    .render()
}

/// Render the `stats` op for a fault-tolerant front end: the replica
/// objects gain health/liveness fields, and a top-level `front_door`
/// object carries the robustness counters.
pub fn render_front_stats(snap: &FrontSnapshot) -> String {
    let f = &snap.front;
    Json::obj(vec![
        ("ok", Json::Bool(true)),
        (
            "replicas",
            Json::arr(snap.replicas.iter().map(|r| {
                let mut fields = replica_fields(&r.name, &r.snapshot);
                fields.push(("health", Json::str(r.health)));
                fields.push(("alive", Json::Bool(r.alive)));
                fields.push(("front_inflight", Json::num(r.inflight as f64)));
                Json::obj(fields)
            })),
        ),
        (
            "front_door",
            Json::obj(vec![
                ("submitted", Json::num(f.submitted as f64)),
                ("completed", Json::num(f.completed as f64)),
                ("shed", Json::num(f.shed as f64)),
                ("retries_honored", Json::num(f.retries_honored as f64)),
                ("replays", Json::num(f.replays as f64)),
                ("replica_failures", Json::num(f.replica_failures as f64)),
                ("replica_restarts", Json::num(f.replica_restarts as f64)),
                ("recovered", Json::num(f.recovered as f64)),
                ("replies_dropped", Json::num(f.replies_dropped as f64)),
                ("journal_appends", Json::num(f.journal_appends as f64)),
                ("journal_bytes", Json::num(f.journal_bytes as f64)),
                ("journal_errors", Json::num(f.journal_errors as f64)),
            ]),
        ),
    ])
    .render()
}

/// The overload shed response: retry after the given backoff.
pub fn render_shed(retry_after_ms: u64) -> String {
    Json::obj(vec![
        ("ok", Json::Bool(false)),
        ("err", Json::str("overloaded")),
        ("retry_after_ms", Json::num(retry_after_ms as f64)),
    ])
    .render()
}

pub fn render_error(msg: &str) -> String {
    Json::obj(vec![("ok", Json::Bool(false)), ("error", Json::str(msg))]).render()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_generate_text() {
        let r = parse_request(
            r#"{"op":"generate","prompt":"hi","max_tokens":4,"temperature":0.5}"#,
        )
        .unwrap();
        match r {
            ServerRequest::Generate { prompt, params, variant, retry } => {
                assert_eq!(prompt, vec![104, 105]);
                assert_eq!(params.max_tokens, 4);
                assert!((params.temperature - 0.5).abs() < 1e-6);
                assert!(variant.is_none());
                assert_eq!(retry, 0);
            }
            _ => panic!("wrong request"),
        }
    }

    #[test]
    fn parses_retry_marker() {
        let r = parse_request(r#"{"op":"generate","prompt":"hi","retry":2}"#).unwrap();
        match r {
            ServerRequest::Generate { retry, .. } => assert_eq!(retry, 2),
            _ => panic!("wrong request"),
        }
    }

    #[test]
    fn renders_shed() {
        let s = render_shed(40);
        let j = Json::parse(&s).unwrap();
        assert_eq!(j.get("ok").and_then(Json::as_bool), Some(false));
        assert_eq!(j.get("err").and_then(Json::as_str), Some("overloaded"));
        assert_eq!(j.get("retry_after_ms").and_then(Json::as_usize), Some(40));
    }

    #[test]
    fn parses_generate_tokens_and_variant() {
        let r = parse_request(
            r#"{"op":"generate","prompt_tokens":[1,2,3],"variant":"tardis80"}"#,
        )
        .unwrap();
        match r {
            ServerRequest::Generate { prompt, variant, .. } => {
                assert_eq!(prompt, vec![1, 2, 3]);
                assert_eq!(variant.as_deref(), Some("tardis80"));
            }
            _ => panic!("wrong request"),
        }
    }

    #[test]
    fn parses_priority() {
        let r = parse_request(
            r#"{"op":"generate","prompt":"hi","priority":7}"#,
        )
        .unwrap();
        match r {
            ServerRequest::Generate { params, .. } => {
                assert_eq!(params.priority, 7);
            }
            _ => panic!("wrong request"),
        }
        let r = parse_request(r#"{"op":"generate","prompt":"hi"}"#).unwrap();
        match r {
            ServerRequest::Generate { params, .. } => {
                assert_eq!(params.priority, 0, "priority defaults to 0");
            }
            _ => panic!("wrong request"),
        }
    }

    #[test]
    fn parses_deadlines() {
        let r = parse_request(
            r#"{"op":"generate","prompt":"hi","ttft_deadline_ms":50,"tpot_deadline_ms":20}"#,
        )
        .unwrap();
        match r {
            ServerRequest::Generate { params, .. } => {
                assert_eq!(params.ttft_deadline_ms, Some(50));
                assert_eq!(params.tpot_deadline_ms, Some(20));
                assert!(!params.degrade, "wire can never request degrade");
            }
            _ => panic!("wrong request"),
        }
        let r = parse_request(r#"{"op":"generate","prompt":"hi","degrade":true}"#).unwrap();
        match r {
            ServerRequest::Generate { params, .. } => {
                assert_eq!(params.ttft_deadline_ms, None, "no deadline by default");
                assert_eq!(params.tpot_deadline_ms, None);
                assert!(!params.degrade, "degrade on the wire is ignored");
            }
            _ => panic!("wrong request"),
        }
    }

    #[test]
    fn renders_stats() {
        let snap = EngineSnapshot {
            policy: "spf",
            queue_depth: 3,
            queue_pressure: 0.25,
            active_slots: 2,
            inflight_prefills: 1,
            slots_total: 8,
            kv_blocks_total: 64,
            kv_blocks_used: 16,
            block_utilization: 0.25,
            swapped: 1,
            preemptions: 7,
            mixed_step_ratio: Some(0.5),
            mean_occupancy: 1.5,
            tokens_generated: 42,
            admitted: 6,
            finished: 5,
            iterations: 99,
            ffn_fallback_rate: None,
            ffn_last_step_fallback_rate: None,
            prefix_cached_blocks: 5,
            prefix_evictable_blocks: 2,
            prefix_hit_tokens: 120,
            prefix_shared_blocks: 9,
            cow_copies: 3,
            prefix_evictions: 4,
        };
        let s = render_stats(&[("dense".to_string(), snap)]);
        let j = Json::parse(&s).unwrap();
        assert_eq!(j.get("ok").and_then(Json::as_bool), Some(true));
        let reps = j.get("replicas").and_then(Json::as_arr).unwrap();
        assert_eq!(reps.len(), 1);
        assert_eq!(reps[0].get("variant").and_then(Json::as_str), Some("dense"));
        assert_eq!(reps[0].get("policy").and_then(Json::as_str), Some("spf"));
        assert_eq!(reps[0].get("queue_depth").and_then(Json::as_usize), Some(3));
        assert_eq!(reps[0].get("tokens_generated").and_then(Json::as_usize), Some(42));
        // paged-KV serving metrics
        assert_eq!(reps[0].get("kv_blocks_total").and_then(Json::as_usize), Some(64));
        assert_eq!(reps[0].get("kv_blocks_used").and_then(Json::as_usize), Some(16));
        let util = reps[0]
            .get("block_utilization")
            .and_then(Json::as_f64)
            .unwrap();
        assert!((util - 0.25).abs() < 1e-12);
        assert_eq!(reps[0].get("preemptions").and_then(Json::as_usize), Some(7));
        assert_eq!(reps[0].get("swapped").and_then(Json::as_usize), Some(1));
        let mixed = reps[0]
            .get("mixed_step_ratio")
            .and_then(Json::as_f64)
            .unwrap();
        assert!((mixed - 0.5).abs() < 1e-12);
        // no partially-linear FFN -> explicit null
        assert_eq!(reps[0].get("ffn_fallback_rate"), Some(&Json::Null));
        // prefix-cache counters
        assert_eq!(reps[0].get("prefix_cached_blocks").and_then(Json::as_usize), Some(5));
        assert_eq!(reps[0].get("prefix_evictable_blocks").and_then(Json::as_usize), Some(2));
        assert_eq!(reps[0].get("prefix_hit_tokens").and_then(Json::as_usize), Some(120));
        assert_eq!(reps[0].get("prefix_shared_blocks").and_then(Json::as_usize), Some(9));
        assert_eq!(reps[0].get("cow_copies").and_then(Json::as_usize), Some(3));
        assert_eq!(reps[0].get("prefix_evictions").and_then(Json::as_usize), Some(4));
    }

    #[test]
    fn renders_ffn_fallback_rate_when_present() {
        let snap = EngineSnapshot {
            policy: "fifo",
            queue_depth: 0,
            queue_pressure: 0.0,
            active_slots: 0,
            inflight_prefills: 0,
            slots_total: 4,
            kv_blocks_total: 4,
            kv_blocks_used: 0,
            block_utilization: 0.0,
            swapped: 0,
            preemptions: 0,
            mixed_step_ratio: None,
            mean_occupancy: 0.0,
            tokens_generated: 0,
            admitted: 0,
            finished: 0,
            iterations: 1,
            ffn_fallback_rate: Some(0.125),
            ffn_last_step_fallback_rate: Some(0.25),
            prefix_cached_blocks: 0,
            prefix_evictable_blocks: 0,
            prefix_hit_tokens: 0,
            prefix_shared_blocks: 0,
            cow_copies: 0,
            prefix_evictions: 0,
        };
        let s = render_stats(&[("tardis80".to_string(), snap)]);
        let j = Json::parse(&s).unwrap();
        let reps = j.get("replicas").and_then(Json::as_arr).unwrap();
        let rate = reps[0]
            .get("ffn_fallback_rate")
            .and_then(Json::as_f64)
            .unwrap();
        assert!((rate - 0.125).abs() < 1e-12);
        let last = reps[0]
            .get("ffn_last_step_fallback_rate")
            .and_then(Json::as_f64)
            .unwrap();
        assert!((last - 0.25).abs() < 1e-12);
    }

    #[test]
    fn renders_front_stats_with_health_and_counters() {
        use crate::coordinator::router::{FrontDoorStats, ReplicaView};
        let snap = EngineSnapshot {
            policy: "fifo",
            queue_depth: 0,
            queue_pressure: 0.0,
            active_slots: 0,
            inflight_prefills: 0,
            slots_total: 4,
            kv_blocks_total: 4,
            kv_blocks_used: 0,
            block_utilization: 0.0,
            swapped: 0,
            preemptions: 0,
            mixed_step_ratio: None,
            mean_occupancy: 0.0,
            tokens_generated: 0,
            admitted: 0,
            finished: 0,
            iterations: 0,
            ffn_fallback_rate: None,
            ffn_last_step_fallback_rate: None,
            prefix_cached_blocks: 0,
            prefix_evictable_blocks: 0,
            prefix_hit_tokens: 0,
            prefix_shared_blocks: 0,
            cow_copies: 0,
            prefix_evictions: 0,
        };
        let front = FrontSnapshot {
            front: FrontDoorStats {
                submitted: 9,
                completed: 7,
                shed: 2,
                replays: 1,
                replica_failures: 1,
                replica_restarts: 1,
                journal_appends: 16,
                ..Default::default()
            },
            replicas: vec![ReplicaView {
                name: "mock-0".to_string(),
                health: "degraded",
                alive: false,
                inflight: 3,
                snapshot: snap,
            }],
        };
        let j = Json::parse(&render_front_stats(&front)).unwrap();
        assert_eq!(j.get("ok").and_then(Json::as_bool), Some(true));
        let reps = j.get("replicas").and_then(Json::as_arr).unwrap();
        assert_eq!(reps[0].get("variant").and_then(Json::as_str), Some("mock-0"));
        assert_eq!(reps[0].get("health").and_then(Json::as_str), Some("degraded"));
        assert_eq!(reps[0].get("alive").and_then(Json::as_bool), Some(false));
        assert_eq!(reps[0].get("front_inflight").and_then(Json::as_usize), Some(3));
        let fd = j.get("front_door").unwrap();
        assert_eq!(fd.get("submitted").and_then(Json::as_usize), Some(9));
        assert_eq!(fd.get("completed").and_then(Json::as_usize), Some(7));
        assert_eq!(fd.get("shed").and_then(Json::as_usize), Some(2));
        assert_eq!(fd.get("replays").and_then(Json::as_usize), Some(1));
        assert_eq!(fd.get("replica_failures").and_then(Json::as_usize), Some(1));
        assert_eq!(fd.get("replica_restarts").and_then(Json::as_usize), Some(1));
        assert_eq!(fd.get("journal_appends").and_then(Json::as_usize), Some(16));
        assert_eq!(fd.get("journal_errors").and_then(Json::as_usize), Some(0));
    }

    #[test]
    fn rejects_bad_requests() {
        assert!(parse_request("not json").is_err());
        assert!(parse_request(r#"{"op":"nope"}"#).is_err());
        assert!(parse_request(r#"{"op":"generate"}"#).is_err());
        assert!(parse_request(r#"{"op":"generate","prompt":""}"#).is_err());
    }

    #[test]
    fn text_roundtrip() {
        let s = "the falcon folds";
        assert_eq!(decode_tokens(&encode_text(s)), s);
    }

    #[test]
    fn ping_and_stats() {
        assert_eq!(parse_request(r#"{"op":"ping"}"#).unwrap(), ServerRequest::Ping);
        assert_eq!(parse_request(r#"{"op":"stats"}"#).unwrap(), ServerRequest::Stats);
    }
}
