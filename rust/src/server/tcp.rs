//! TCP front-end: accept loop on a worker pool, front-end on its own
//! thread.
//!
//! The serve thread multiplexes any [`FrontEnd`]: it drains the inbound
//! channel (admission, with explicit shed/reject responses), pumps the
//! front end, and dispatches completed replies back to the originating
//! connection's channel. With the synchronous
//! [`Router`](crate::coordinator::router::Router) the engines step on
//! the serve thread itself (PJRT buffers never cross a thread
//! boundary); with the fault-tolerant
//! [`FrontDoor`](crate::coordinator::router::FrontDoor) the serve thread
//! only routes, and replicas step on their own workers.
//!
//! Inbound frames are capped at [`MAX_LINE_BYTES`]; oversized frames get
//! a protocol error and the connection is closed rather than buffering
//! without bound.

use std::collections::HashMap;
use std::io::{BufRead, BufReader, Read, Write};
use std::net::{TcpListener, TcpStream};
use std::sync::mpsc::{channel, Receiver, Sender};
use std::time::Duration;

use anyhow::Result;

use crate::coordinator::request::SamplingParams;
use crate::coordinator::router::{FrontEnd, SubmitOutcome};
use crate::util::json::Json;
use crate::util::rng::Rng;
use crate::util::threadpool::ThreadPool;

use super::protocol::{
    parse_request, render_completion, render_error, render_front_stats, render_shed,
    ServerRequest,
};

/// Hard cap on one inbound request line (1 MiB). A line that exceeds it
/// is answered with a protocol error and the connection is closed.
pub const MAX_LINE_BYTES: usize = 1 << 20;

enum FrontMsg {
    Generate {
        prompt: Vec<i32>,
        params: SamplingParams,
        variant: Option<String>,
        retry: u64,
        reply: Sender<String>,
    },
    Stats {
        reply: Sender<String>,
    },
}

/// Serve `front` on `addr` until `max_requests` generate calls complete
/// (None = forever). Returns the number of requests served. Stats calls,
/// rejected requests, and journal-recovered replays don't count toward
/// the target.
pub fn serve<F: FrontEnd>(
    mut front: F,
    addr: &str,
    max_requests: Option<usize>,
) -> Result<usize> {
    let listener = TcpListener::bind(addr)?;
    let local = listener.local_addr()?;
    eprintln!("[server] listening on {local}");
    let (tx, rx): (Sender<FrontMsg>, Receiver<FrontMsg>) = channel();

    // Accept loop on the pool; front-end loop on this thread.
    let pool = ThreadPool::new(4);
    let accept_tx = tx.clone();
    let served_target = max_requests;
    std::thread::spawn(move || {
        for stream in listener.incoming() {
            let Ok(stream) = stream else { continue };
            let tx = accept_tx.clone();
            pool.execute(move || handle_conn(stream, tx));
        }
    });

    let mut served = 0usize;
    // front-end ticket -> reply channel
    let mut waiting: HashMap<u64, Sender<String>> = HashMap::new();
    loop {
        // Admit whatever has arrived.
        while let Ok(msg) = rx.try_recv() {
            match msg {
                FrontMsg::Stats { reply } => {
                    let _ = reply.send(render_front_stats(&front.front_snapshot()));
                }
                FrontMsg::Generate { prompt, params, variant, retry, reply } => {
                    match front.submit_front(variant.as_deref(), prompt, params, retry > 0)
                    {
                        SubmitOutcome::Admitted { ticket, drop_reply } => {
                            if drop_reply {
                                // Injected dropconn fault: the client
                                // vanishes; the reply has nowhere to go.
                                drop(reply);
                            } else {
                                waiting.insert(ticket, reply);
                            }
                        }
                        SubmitOutcome::Shed { retry_after_ms } => {
                            let _ = reply.send(render_shed(retry_after_ms));
                        }
                        SubmitOutcome::Rejected(msg) => {
                            let _ = reply.send(render_error(&msg));
                        }
                    }
                }
            }
        }
        // Make progress.
        front.pump(Duration::from_millis(1))?;
        for r in front.take_replies() {
            let rendered = match &r.result {
                Ok(c) => render_completion(c, &r.replica),
                Err(e) => render_error(e),
            };
            match waiting.remove(&r.ticket) {
                Some(reply) => {
                    if reply.send(rendered).is_err() {
                        front.note_reply_dropped();
                    }
                }
                // Recovered replays never had a live waiter; anything
                // else missing means the client disconnected mid-stream.
                None if !r.recovered => front.note_reply_dropped(),
                None => {}
            }
            if !r.recovered && r.result.is_ok() {
                served += 1;
            }
        }
        if let Some(target) = served_target {
            if served >= target {
                return Ok(served);
            }
        }
    }
}

/// Read one `\n`-terminated frame, at most [`MAX_LINE_BYTES`] long.
enum Frame {
    Line(String),
    /// EOF (clean, or a half-written final frame — dropped either way).
    Eof,
    Oversized,
}

fn read_frame(reader: &mut BufReader<TcpStream>) -> Frame {
    let mut buf = Vec::new();
    let n = match reader
        .take((MAX_LINE_BYTES + 1) as u64)
        .read_until(b'\n', &mut buf)
    {
        Ok(n) => n,
        Err(_) => return Frame::Eof,
    };
    if n == 0 {
        return Frame::Eof;
    }
    if buf.last() != Some(&b'\n') {
        // No terminator: either the line kept going past the cap, or the
        // peer closed mid-frame.
        if buf.len() > MAX_LINE_BYTES {
            return Frame::Oversized;
        }
        return Frame::Eof;
    }
    Frame::Line(String::from_utf8_lossy(&buf).into_owned())
}

fn handle_conn(stream: TcpStream, tx: Sender<FrontMsg>) {
    let mut writer = match stream.try_clone() {
        Ok(w) => w,
        Err(_) => return,
    };
    let mut reader = BufReader::new(stream);
    loop {
        let line = match read_frame(&mut reader) {
            Frame::Eof => break,
            Frame::Oversized => {
                let msg =
                    render_error(&format!("line exceeds {MAX_LINE_BYTES} byte limit"));
                let _ = writer.write_all(msg.as_bytes());
                let _ = writer.write_all(b"\n");
                break;
            }
            Frame::Line(l) => l,
        };
        if line.trim().is_empty() {
            continue;
        }
        let response = match parse_request(&line) {
            Err(e) => render_error(&e.to_string()),
            Ok(ServerRequest::Ping) => r#"{"ok":true,"pong":true}"#.to_string(),
            Ok(ServerRequest::Stats) => {
                // The serve thread owns the front end; ask it for a
                // snapshot the same way generate results flow back.
                let (reply_tx, reply_rx) = channel();
                if tx.send(FrontMsg::Stats { reply: reply_tx }).is_err() {
                    render_error("engine shut down")
                } else {
                    match reply_rx.recv_timeout(Duration::from_secs(10)) {
                        Ok(r) => r,
                        Err(_) => render_error("timeout"),
                    }
                }
            }
            Ok(ServerRequest::Generate { prompt, params, variant, retry }) => {
                let (reply_tx, reply_rx) = channel();
                let msg =
                    FrontMsg::Generate { prompt, params, variant, retry, reply: reply_tx };
                if tx.send(msg).is_err() {
                    render_error("engine shut down")
                } else {
                    match reply_rx.recv_timeout(Duration::from_secs(120)) {
                        Ok(r) => r,
                        Err(_) => render_error("timeout"),
                    }
                }
            }
        };
        if writer.write_all(response.as_bytes()).is_err()
            || writer.write_all(b"\n").is_err()
        {
            break;
        }
    }
}

/// Minimal client for tests/examples: send one line, read one line.
pub fn client_roundtrip(addr: &str, line: &str) -> Result<String> {
    let mut stream = TcpStream::connect(addr)?;
    stream.write_all(line.as_bytes())?;
    stream.write_all(b"\n")?;
    let mut reader = BufReader::new(stream);
    let mut response = String::new();
    reader.read_line(&mut response)?;
    Ok(response.trim().to_string())
}

/// Outcome of [`client_roundtrip_with_retry`].
#[derive(Debug, Clone)]
pub struct RetryOutcome {
    /// The final response line (possibly still an `overloaded` shed if
    /// `max_attempts` ran out).
    pub response: String,
    /// Round trips performed (1 = no retries needed).
    pub attempts: u32,
}

/// `overloaded` shed responses carry `retry_after_ms`; extract it.
fn shed_backoff_ms(response: &str) -> Option<u64> {
    let j = Json::parse(response).ok()?;
    if j.get("err").and_then(Json::as_str) != Some("overloaded") {
        return None;
    }
    Some(j.get("retry_after_ms").and_then(Json::as_i64).unwrap_or(25).max(0) as u64)
}

/// Re-render `line` with a `"retry":attempt` marker so the server can
/// count honored retries. Non-object lines pass through untouched.
fn with_retry_marker(line: &str, attempt: u32) -> String {
    match Json::parse(line.trim()) {
        Ok(Json::Obj(mut m)) => {
            m.insert("retry".to_string(), Json::num(attempt as f64));
            Json::Obj(m).render()
        }
        _ => line.to_string(),
    }
}

/// [`client_roundtrip`] with shed-aware retry: on an `overloaded`
/// response, sleep `retry_after_ms` plus deterministic jitter (seeded
/// `Rng`, so tests reproduce) and resend with a `"retry":N` marker, up
/// to `max_attempts` total round trips.
pub fn client_roundtrip_with_retry(
    addr: &str,
    line: &str,
    max_attempts: u32,
    seed: u64,
) -> Result<RetryOutcome> {
    assert!(max_attempts >= 1);
    let mut rng = Rng::new(seed);
    let mut attempt = 0u32;
    loop {
        let sent = if attempt == 0 {
            line.to_string()
        } else {
            with_retry_marker(line, attempt)
        };
        let response = client_roundtrip(addr, &sent)?;
        attempt += 1;
        match shed_backoff_ms(&response) {
            Some(backoff_ms) if attempt < max_attempts => {
                let jitter = rng.below(backoff_ms / 2 + 1);
                std::thread::sleep(Duration::from_millis(backoff_ms + jitter));
            }
            _ => return Ok(RetryOutcome { response, attempts: attempt }),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::engine_loop::{EngineConfig, InferenceEngine};
    use crate::coordinator::model::MockModel;
    use crate::coordinator::router::{FrontDoor, FrontDoorConfig, ReplicaFactory, Router};

    fn ephemeral_addr() -> String {
        // Port 0 = ephemeral; learn the port via a pre-bound listener.
        let listener = std::net::TcpListener::bind("127.0.0.1:0").unwrap();
        let addr = listener.local_addr().unwrap().to_string();
        drop(listener);
        addr
    }

    #[test]
    fn serves_generate_over_tcp() {
        let router = Router::new(vec![(
            "mock".to_string(),
            InferenceEngine::new(
                MockModel::new(2, 64, 256, vec![4, 8]),
                EngineConfig::default(),
            ),
        )]);
        let addr = ephemeral_addr();
        let addr2 = addr.clone();
        let h = std::thread::spawn(move || serve(router, &addr2, Some(1)));
        std::thread::sleep(Duration::from_millis(100));
        let resp = client_roundtrip(
            &addr,
            r#"{"op":"generate","prompt":"ab","max_tokens":3}"#,
        )
        .unwrap();
        assert!(resp.contains("\"ok\":true"), "{resp}");
        assert!(resp.contains("\"reason\":\"length\""), "{resp}");
        let served = h.join().unwrap().unwrap();
        assert_eq!(served, 1);
    }

    #[test]
    fn stats_over_tcp_reports_replicas() {
        let router = Router::new(vec![(
            "mock".to_string(),
            InferenceEngine::new(
                MockModel::new(2, 64, 256, vec![4, 8]),
                EngineConfig::default(),
            ),
        )]);
        let addr = ephemeral_addr();
        let addr2 = addr.clone();
        let h = std::thread::spawn(move || serve(router, &addr2, Some(1)));
        std::thread::sleep(Duration::from_millis(100));
        let resp = client_roundtrip(&addr, r#"{"op":"stats"}"#).unwrap();
        assert!(resp.contains("\"ok\":true"), "{resp}");
        assert!(resp.contains("\"replicas\""), "{resp}");
        assert!(resp.contains("\"variant\":\"mock\""), "{resp}");
        assert!(resp.contains("\"policy\":\"fifo\""), "{resp}");
        assert!(resp.contains("\"queue_depth\":0"), "{resp}");
        assert!(resp.contains("\"slots_total\":2"), "{resp}");
        // paged-KV metrics (mock = degenerate one-block-per-slot layout)
        assert!(resp.contains("\"kv_blocks_total\":2"), "{resp}");
        assert!(resp.contains("\"preemptions\":0"), "{resp}");
        assert!(resp.contains("\"block_utilization\":"), "{resp}");
        // Front-door counters render for the synchronous tier too.
        assert!(resp.contains("\"front_door\""), "{resp}");
        assert!(resp.contains("\"health\":\"healthy\""), "{resp}");
        // One generate terminates the server (stats don't count).
        let resp = client_roundtrip(
            &addr,
            r#"{"op":"generate","prompt":"ab","max_tokens":2}"#,
        )
        .unwrap();
        assert!(resp.contains("\"ok\":true"), "{resp}");
        let served = h.join().unwrap().unwrap();
        assert_eq!(served, 1);
    }

    fn mock_factory() -> ReplicaFactory<MockModel> {
        Box::new(|| {
            Ok(InferenceEngine::new(
                MockModel::new(2, 64, 256, vec![4, 8]),
                EngineConfig::default(),
            ))
        })
    }

    #[test]
    fn serves_front_door_over_tcp() {
        let front = FrontDoor::new(
            vec![
                ("mock".to_string(), mock_factory()),
                ("mock".to_string(), mock_factory()),
            ],
            FrontDoorConfig::default(),
        )
        .unwrap();
        let addr = ephemeral_addr();
        let addr2 = addr.clone();
        let h = std::thread::spawn(move || serve(front, &addr2, Some(2)));
        std::thread::sleep(Duration::from_millis(100));
        let stats = client_roundtrip(&addr, r#"{"op":"stats"}"#).unwrap();
        assert!(stats.contains("\"variant\":\"mock-0\""), "{stats}");
        assert!(stats.contains("\"variant\":\"mock-1\""), "{stats}");
        assert!(stats.contains("\"alive\":true"), "{stats}");
        assert!(stats.contains("\"front_door\""), "{stats}");
        for _ in 0..2 {
            let resp = client_roundtrip(
                &addr,
                r#"{"op":"generate","prompt":"ab","max_tokens":3}"#,
            )
            .unwrap();
            assert!(resp.contains("\"ok\":true"), "{resp}");
        }
        let served = h.join().unwrap().unwrap();
        assert_eq!(served, 2);
    }

    #[test]
    fn rejects_oversized_and_survives_malformed_frames() {
        let router = Router::new(vec![(
            "mock".to_string(),
            InferenceEngine::new(
                MockModel::new(2, 64, 256, vec![4, 8]),
                EngineConfig::default(),
            ),
        )]);
        let addr = ephemeral_addr();
        let addr2 = addr.clone();
        let h = std::thread::spawn(move || serve(router, &addr2, Some(1)));
        std::thread::sleep(Duration::from_millis(100));
        // Oversized frame: error response, connection closed.
        let big = format!("{{\"op\":\"generate\",\"prompt\":\"{}\"}}", "x".repeat(MAX_LINE_BYTES));
        let resp = client_roundtrip(&addr, &big).unwrap();
        assert!(resp.contains("byte limit"), "{resp}");
        // Malformed json: error response, server keeps serving.
        let resp = client_roundtrip(&addr, "this is not json").unwrap();
        assert!(resp.contains("\"ok\":false"), "{resp}");
        // Half-written frame (no newline, then close): dropped silently.
        {
            let mut s = TcpStream::connect(&addr).unwrap();
            s.write_all(b"{\"op\":\"gener").unwrap();
        }
        // The server is still healthy.
        let resp = client_roundtrip(
            &addr,
            r#"{"op":"generate","prompt":"ab","max_tokens":2}"#,
        )
        .unwrap();
        assert!(resp.contains("\"ok\":true"), "{resp}");
        let served = h.join().unwrap().unwrap();
        assert_eq!(served, 1);
    }

    #[test]
    fn retry_helper_marks_and_parses() {
        let marked = with_retry_marker(r#"{"op":"generate","prompt":"hi"}"#, 2);
        let j = Json::parse(&marked).unwrap();
        assert_eq!(j.get("retry").and_then(Json::as_usize), Some(2));
        assert_eq!(j.get("op").and_then(Json::as_str), Some("generate"));
        assert_eq!(shed_backoff_ms(&render_shed(40)), Some(40));
        assert_eq!(shed_backoff_ms(r#"{"ok":true}"#), None);
        assert_eq!(shed_backoff_ms("garbage"), None);
    }
}
