//! TCP front-end: accept loop on a worker pool, engine on its own thread.
//!
//! The engine thread multiplexes: it drains the inbound channel into the
//! router (admission), steps the router, and dispatches completions back
//! to the originating connection's channel. PJRT buffers never cross a
//! thread boundary.

use std::collections::HashMap;
use std::io::{BufRead, BufReader, Write};
use std::net::{TcpListener, TcpStream};
use std::sync::mpsc::{channel, Receiver, Sender};
use std::time::Duration;

use anyhow::Result;

use crate::coordinator::model::StepModel;
use crate::coordinator::router::Router;
use crate::util::threadpool::ThreadPool;

use super::protocol::{
    parse_request, render_completion, render_error, render_stats, ServerRequest,
};

enum ToEngine {
    Generate {
        line_req: ServerRequest,
        reply: Sender<String>,
    },
    Stats {
        reply: Sender<String>,
    },
    Shutdown,
}

/// Serve `router` on `addr` until `max_requests` generate calls complete
/// (None = forever). Returns the number of requests served.
pub fn serve<M: StepModel>(
    mut router: Router<M>,
    addr: &str,
    max_requests: Option<usize>,
) -> Result<usize> {
    let listener = TcpListener::bind(addr)?;
    let local = listener.local_addr()?;
    eprintln!("[server] listening on {local}");
    let (tx, rx): (Sender<ToEngine>, Receiver<ToEngine>) = channel();

    // Accept loop on the pool; engine loop on this thread.
    let pool = ThreadPool::new(4);
    let accept_tx = tx.clone();
    let served_target = max_requests;
    std::thread::spawn(move || {
        for stream in listener.incoming() {
            let Ok(stream) = stream else { continue };
            let tx = accept_tx.clone();
            pool.execute(move || handle_conn(stream, tx));
        }
    });

    let mut served = 0usize;
    // ticket -> (reply channel, replica name)
    let mut waiting: HashMap<(usize, u64), Sender<String>> = HashMap::new();
    loop {
        // Admit whatever has arrived.
        while let Ok(msg) = rx.try_recv() {
            match msg {
                ToEngine::Shutdown => return Ok(served),
                ToEngine::Stats { reply } => {
                    let _ = reply.send(render_stats(&router.stats_snapshot()));
                }
                ToEngine::Generate { line_req, reply } => {
                    if let ServerRequest::Generate { prompt, params, variant } =
                        line_req
                    {
                        match router.submit(variant.as_deref(), prompt, params) {
                            Ok(t) => {
                                waiting.insert((t.replica, t.request), reply);
                            }
                            Err(e) => {
                                let _ = reply.send(render_error(&e.to_string()));
                            }
                        }
                    }
                }
            }
        }
        // Make progress.
        let busy = router.step_all()?;
        for i in 0..router.n_replicas() {
            let name = router.replica(i).name.clone();
            for c in router.replica(i).engine.take_completions() {
                if let Some(reply) = waiting.remove(&(i, c.id)) {
                    let _ = reply.send(render_completion(&c, &name));
                    served += 1;
                }
            }
        }
        if let Some(target) = served_target {
            if served >= target {
                return Ok(served);
            }
        }
        if !busy && waiting.is_empty() {
            std::thread::sleep(Duration::from_millis(1));
        }
    }
}

fn handle_conn(stream: TcpStream, tx: Sender<ToEngine>) {
    let peer = stream.peer_addr().ok();
    let mut writer = match stream.try_clone() {
        Ok(w) => w,
        Err(_) => return,
    };
    let reader = BufReader::new(stream);
    for line in reader.lines() {
        let Ok(line) = line else { break };
        if line.trim().is_empty() {
            continue;
        }
        let response = match parse_request(&line) {
            Err(e) => render_error(&e.to_string()),
            Ok(ServerRequest::Ping) => r#"{"ok":true,"pong":true}"#.to_string(),
            Ok(ServerRequest::Stats) => {
                // The engine thread owns the router; ask it for a
                // snapshot the same way generate results flow back.
                let (reply_tx, reply_rx) = channel();
                if tx.send(ToEngine::Stats { reply: reply_tx }).is_err() {
                    render_error("engine shut down")
                } else {
                    match reply_rx.recv_timeout(Duration::from_secs(10)) {
                        Ok(r) => r,
                        Err(_) => render_error("timeout"),
                    }
                }
            }
            Ok(req @ ServerRequest::Generate { .. }) => {
                let (reply_tx, reply_rx) = channel();
                if tx
                    .send(ToEngine::Generate { line_req: req, reply: reply_tx })
                    .is_err()
                {
                    render_error("engine shut down")
                } else {
                    match reply_rx.recv_timeout(Duration::from_secs(120)) {
                        Ok(r) => r,
                        Err(_) => render_error("timeout"),
                    }
                }
            }
        };
        if writer.write_all(response.as_bytes()).is_err()
            || writer.write_all(b"\n").is_err()
        {
            break;
        }
    }
    let _ = peer;
}

/// Minimal client for tests/examples: send one line, read one line.
pub fn client_roundtrip(addr: &str, line: &str) -> Result<String> {
    let mut stream = TcpStream::connect(addr)?;
    stream.write_all(line.as_bytes())?;
    stream.write_all(b"\n")?;
    let mut reader = BufReader::new(stream);
    let mut response = String::new();
    reader.read_line(&mut response)?;
    Ok(response.trim().to_string())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::engine_loop::{EngineConfig, InferenceEngine};
    use crate::coordinator::model::MockModel;

    #[test]
    fn serves_generate_over_tcp() {
        let router = Router::new(vec![(
            "mock".to_string(),
            InferenceEngine::new(
                MockModel::new(2, 64, 256, vec![4, 8]),
                EngineConfig::default(),
            ),
        )]);
        // Port 0 = ephemeral; learn the port via a pre-bound listener.
        let listener = std::net::TcpListener::bind("127.0.0.1:0").unwrap();
        let addr = listener.local_addr().unwrap().to_string();
        drop(listener);
        let addr2 = addr.clone();
        let h = std::thread::spawn(move || serve(router, &addr2, Some(1)));
        std::thread::sleep(Duration::from_millis(100));
        let resp = client_roundtrip(
            &addr,
            r#"{"op":"generate","prompt":"ab","max_tokens":3}"#,
        )
        .unwrap();
        assert!(resp.contains("\"ok\":true"), "{resp}");
        assert!(resp.contains("\"reason\":\"length\""), "{resp}");
        let served = h.join().unwrap().unwrap();
        assert_eq!(served, 1);
    }

    #[test]
    fn stats_over_tcp_reports_replicas() {
        let router = Router::new(vec![(
            "mock".to_string(),
            InferenceEngine::new(
                MockModel::new(2, 64, 256, vec![4, 8]),
                EngineConfig::default(),
            ),
        )]);
        let listener = std::net::TcpListener::bind("127.0.0.1:0").unwrap();
        let addr = listener.local_addr().unwrap().to_string();
        drop(listener);
        let addr2 = addr.clone();
        let h = std::thread::spawn(move || serve(router, &addr2, Some(1)));
        std::thread::sleep(Duration::from_millis(100));
        let resp = client_roundtrip(&addr, r#"{"op":"stats"}"#).unwrap();
        assert!(resp.contains("\"ok\":true"), "{resp}");
        assert!(resp.contains("\"replicas\""), "{resp}");
        assert!(resp.contains("\"variant\":\"mock\""), "{resp}");
        assert!(resp.contains("\"policy\":\"fifo\""), "{resp}");
        assert!(resp.contains("\"queue_depth\":0"), "{resp}");
        assert!(resp.contains("\"slots_total\":2"), "{resp}");
        // paged-KV metrics (mock = degenerate one-block-per-slot layout)
        assert!(resp.contains("\"kv_blocks_total\":2"), "{resp}");
        assert!(resp.contains("\"preemptions\":0"), "{resp}");
        assert!(resp.contains("\"block_utilization\":"), "{resp}");
        // One generate terminates the server (stats don't count).
        let resp = client_roundtrip(
            &addr,
            r#"{"op":"generate","prompt":"ab","max_tokens":2}"#,
        )
        .unwrap();
        assert!(resp.contains("\"ok\":true"), "{resp}");
        let served = h.join().unwrap().unwrap();
        assert_eq!(served, 1);
    }
}
