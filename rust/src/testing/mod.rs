//! Test harnesses: proptest-lite property testing and the trace-driven
//! workload generator/replayer ([`trace`]).
//!
//! Proptest-lite: seeded random-input property testing (the real proptest
//! crate is not in the offline vendor set).
//!
//! ```no_run
//! use tardis::testing::property;
//! property("alloc never double-allocates", 200, |rng| {
//!     // build random input from rng, assert the invariant, return
//!     // Err(description) to fail.
//!     Ok(())
//! });
//! ```
//! On failure the seed of the failing case is printed so it can be
//! replayed with `property_seeded`.

pub mod trace;

use crate::util::rng::Rng;

pub type PropResult = Result<(), String>;

/// Run `f` over `cases` independently-seeded random cases; panic with the
/// failing seed + message on the first failure.
pub fn property<F: FnMut(&mut Rng) -> PropResult>(name: &str, cases: u64, f: F) {
    property_base(name, cases, 0xDEC0DE, f)
}

/// Replay a specific failing seed.
pub fn property_seeded<F: FnMut(&mut Rng) -> PropResult>(
    name: &str,
    seed: u64,
    mut f: F,
) {
    let mut rng = Rng::new(seed);
    if let Err(msg) = f(&mut rng) {
        panic!("property '{name}' failed at seed {seed:#x}: {msg}");
    }
}

fn property_base<F: FnMut(&mut Rng) -> PropResult>(
    name: &str,
    cases: u64,
    base_seed: u64,
    mut f: F,
) {
    for case in 0..cases {
        let seed = base_seed ^ case.wrapping_mul(0x9E37_79B9_7F4A_7C15);
        let mut rng = Rng::new(seed);
        if let Err(msg) = f(&mut rng) {
            panic!(
                "property '{name}' failed on case {case}/{cases} \
                 (replay with property_seeded(_, {seed:#x}, _)): {msg}"
            );
        }
    }
}

/// Assert helper for property bodies.
#[macro_export]
macro_rules! prop_assert {
    ($cond:expr, $($fmt:tt)+) => {
        if !$cond {
            return Err(format!($($fmt)+));
        }
    };
    ($cond:expr) => {
        if !$cond {
            return Err(format!("assertion failed: {}", stringify!($cond)));
        }
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passing_property_runs_all_cases() {
        let mut count = 0;
        property("trivial", 50, |rng| {
            count += 1;
            let v = rng.below(10);
            prop_assert!(v < 10, "v = {v}");
            Ok(())
        });
        assert_eq!(count, 50);
    }

    #[test]
    #[should_panic(expected = "property 'always-fails' failed")]
    fn failing_property_panics_with_seed() {
        property("always-fails", 5, |_| Err("nope".into()));
    }

    #[test]
    fn seeded_replay_is_deterministic() {
        let mut first = None;
        property_seeded("replay", 0x1234, |rng| {
            first = Some(rng.next_u64());
            Ok(())
        });
        let mut second = None;
        property_seeded("replay", 0x1234, |rng| {
            second = Some(rng.next_u64());
            Ok(())
        });
        assert_eq!(first, second);
    }
}
