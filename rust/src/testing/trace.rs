//! Trace-driven workload harness.
//!
//! Three layers, each independently testable:
//!
//! 1. **Generation** — [`TraceSpec`] materializes a workload from a
//!    seeded [`Rng`]: Poisson / bursty / heavy-tail arrivals, prompt and
//!    output length distributions, a shared-prefix mix, weighted
//!    priority tiers with per-tier TTFT/TPOT SLOs, and multi-turn
//!    sessions whose follow-up prompts grow from the previous turn.
//! 2. **Fixtures** — [`dump_jsonl`] / [`load_jsonl`] serialize the
//!    materialized trace as one JSON object per line. Every field is an
//!    integer, so `load(dump(t))` round-trips **bitwise**: a committed
//!    trace is a frozen regression input, never regenerated in CI
//!    (libm differences across toolchains could perturb the sampled
//!    floats, so only the load path is exercised there).
//! 3. **Replay** — [`replay`] drives a trace through an
//!    [`InferenceEngine`] on its deterministic virtual clock: arrivals
//!    release at their recorded microsecond, each engine step costs a
//!    fixed virtual duration, and the overload ladder
//!    ([`OverloadPolicy`]) degrades or sheds at the submission boundary
//!    exactly like the TCP front door. The resulting
//!    [`ReplayReport`] carries per-tier goodput — the fraction of
//!    requests that met both their TTFT and TPOT SLOs.

use std::collections::{HashMap, VecDeque};

use anyhow::{anyhow, Result};

use crate::coordinator::engine_loop::{InferenceEngine, SubmitError};
use crate::coordinator::model::StepModel;
use crate::coordinator::queue::{OverloadAction, OverloadPolicy};
use crate::coordinator::request::{RequestId, SamplingParams};
use crate::util::json::Json;
use crate::util::rng::Rng;

// ---------------------------------------------------------------------------
// Workload specification
// ---------------------------------------------------------------------------

/// Inter-arrival process for session starts.
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum ArrivalModel {
    /// Exponential gaps with the given mean (a Poisson process).
    Poisson { mean_gap_us: u64 },
    /// Poisson bursts: every arrival is a burst of `burst` sessions
    /// spread uniformly over `within_us`.
    Bursty { mean_gap_us: u64, burst: usize, within_us: u64 },
    /// Pareto gaps `scale * (1-u)^(-1/alpha)`: rare long lulls between
    /// packed stretches (`alpha` close to 1 = heavier tail).
    HeavyTail { scale_us: u64, alpha: f64 },
}

/// Token-count distribution for prompts and outputs.
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum LengthModel {
    /// Uniform in `[lo, hi]` inclusive.
    Uniform { lo: usize, hi: usize },
    /// `median * exp(sigma * N(0,1))`, clamped to `[1, max]` — the
    /// right-skewed shape of real prompt logs.
    LogNormal { median: f64, sigma: f64, max: usize },
}

impl LengthModel {
    fn sample(&self, rng: &mut Rng) -> usize {
        match *self {
            LengthModel::Uniform { lo, hi } => {
                rng.range_u64(lo as u64, hi.max(lo) as u64) as usize
            }
            LengthModel::LogNormal { median, sigma, max } => {
                let v = median * (sigma * rng.normal()).exp();
                (v as usize).clamp(1, max.max(1))
            }
        }
    }
}

/// One service tier: a sampling weight, the scheduler priority, and the
/// SLOs its requests are judged against (None = unconstrained).
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct TierSpec {
    pub weight: f64,
    pub priority: i32,
    pub ttft_deadline_ms: Option<u64>,
    pub tpot_deadline_ms: Option<u64>,
}

/// Everything needed to materialize a workload from a seed.
#[derive(Debug, Clone, PartialEq)]
pub struct TraceSpec {
    pub seed: u64,
    /// Number of *sessions*; multi-turn follow-ups add further events.
    pub sessions: usize,
    pub arrivals: ArrivalModel,
    pub prompt_len: LengthModel,
    pub output_len: LengthModel,
    /// Probability a session's first prompt starts with one of
    /// `prefix_pool` shared prefixes of `prefix_len` tokens.
    pub shared_prefix_p: f64,
    pub prefix_pool: usize,
    pub prefix_len: usize,
    /// Weighted service tiers (index = `TraceEvent::tier`).
    pub tiers: Vec<TierSpec>,
    /// Probability each turn spawns a follow-up turn, up to `max_turns`
    /// per session. Follow-ups re-send the grown conversation (previous
    /// prompt + a synthesized response) after a think-time gap.
    pub multi_turn_p: f64,
    pub max_turns: usize,
    pub think_gap_us: u64,
    /// Token ids are drawn uniformly from `[0, vocab)`.
    pub vocab: usize,
}

impl Default for TraceSpec {
    fn default() -> Self {
        TraceSpec {
            seed: 0,
            sessions: 32,
            arrivals: ArrivalModel::Poisson { mean_gap_us: 2_000 },
            prompt_len: LengthModel::Uniform { lo: 4, hi: 24 },
            output_len: LengthModel::Uniform { lo: 2, hi: 8 },
            shared_prefix_p: 0.3,
            prefix_pool: 4,
            prefix_len: 8,
            tiers: vec![TierSpec {
                weight: 1.0,
                priority: 0,
                ttft_deadline_ms: None,
                tpot_deadline_ms: None,
            }],
            multi_turn_p: 0.2,
            max_turns: 3,
            think_gap_us: 10_000,
            vocab: 256,
        }
    }
}

impl TraceSpec {
    /// The spec behind the committed overload fixture
    /// (`rust/tests/data/traces/overload.jsonl`): a burst-heavy backlog
    /// where a latency-sensitive tier with tight TTFT/TPOT SLOs queues
    /// behind a bulk tier with long prompts and no deadlines. FIFO makes
    /// the interactive tier wait out the bulk prompts; EDF does not.
    pub fn overload_preset() -> TraceSpec {
        TraceSpec {
            seed: 0x51_0,
            sessions: 24,
            arrivals: ArrivalModel::Bursty { mean_gap_us: 4_000, burst: 6, within_us: 500 },
            prompt_len: LengthModel::Uniform { lo: 4, hi: 28 },
            output_len: LengthModel::Uniform { lo: 2, hi: 8 },
            shared_prefix_p: 0.25,
            prefix_pool: 3,
            prefix_len: 6,
            tiers: vec![
                // bulk: long prompts tolerated, no deadline, degradable
                TierSpec {
                    weight: 0.5,
                    priority: 0,
                    ttft_deadline_ms: None,
                    tpot_deadline_ms: None,
                },
                // interactive: tight TTFT, modest TPOT
                TierSpec {
                    weight: 0.5,
                    priority: 1,
                    ttft_deadline_ms: Some(30),
                    tpot_deadline_ms: Some(20),
                },
            ],
            multi_turn_p: 0.2,
            max_turns: 2,
            think_gap_us: 8_000,
            vocab: 64,
        }
    }
}

// ---------------------------------------------------------------------------
// The materialized trace
// ---------------------------------------------------------------------------

/// One request of a materialized trace. All fields are integers so the
/// JSONL form round-trips bitwise.
#[derive(Debug, Clone, PartialEq)]
pub struct TraceEvent {
    pub id: u64,
    pub arrival_us: u64,
    pub session: u64,
    pub turn: u32,
    /// Index into the generating spec's `tiers` (kept in the fixture so
    /// replay can attribute goodput without the spec).
    pub tier: usize,
    pub priority: i32,
    pub ttft_deadline_ms: Option<u64>,
    pub tpot_deadline_ms: Option<u64>,
    pub prompt: Vec<i32>,
    pub max_tokens: usize,
}

impl TraceEvent {
    /// Engine-facing sampling parameters: greedy decoding with a
    /// per-request seed, deadlines from the tier, never pre-degraded
    /// (degradation is the replay-time overload ladder's decision).
    pub fn params(&self, seed: u64) -> SamplingParams {
        SamplingParams {
            temperature: 0.0,
            top_k: 0,
            max_tokens: self.max_tokens,
            stop_token: None,
            seed: seed ^ self.id,
            priority: self.priority,
            ttft_deadline_ms: self.ttft_deadline_ms,
            tpot_deadline_ms: self.tpot_deadline_ms,
            degrade: false,
        }
    }
}

fn sample_tier(tiers: &[TierSpec], rng: &mut Rng) -> usize {
    let total: f64 = tiers.iter().map(|t| t.weight.max(0.0)).sum();
    if total <= 0.0 {
        return 0;
    }
    let mut x = rng.f64() * total;
    for (i, t) in tiers.iter().enumerate() {
        x -= t.weight.max(0.0);
        if x < 0.0 {
            return i;
        }
    }
    tiers.len() - 1
}

/// Materialize the workload. Deterministic in `spec` (one fixed draw
/// order from a single seeded stream); the result is sorted by
/// `(arrival_us, id)`.
pub fn generate(spec: &TraceSpec) -> Vec<TraceEvent> {
    assert!(!spec.tiers.is_empty(), "need at least one tier");
    assert!(spec.vocab > 0, "need a non-empty vocab");
    let mut rng = Rng::new(spec.seed);
    let vocab = spec.vocab as u64;
    let token = |rng: &mut Rng| rng.below(vocab) as i32;
    let prefixes: Vec<Vec<i32>> = (0..spec.prefix_pool)
        .map(|_| (0..spec.prefix_len).map(|_| token(&mut rng)).collect())
        .collect();
    let mut events = Vec::new();
    let mut id = 0u64;
    let mut now_us = 0u64;
    for session in 0..spec.sessions as u64 {
        let gap = match spec.arrivals {
            ArrivalModel::Poisson { mean_gap_us } => rng.exp(mean_gap_us as f64),
            // the gap opens a burst; intra-burst offsets are drawn below
            ArrivalModel::Bursty { mean_gap_us, .. } => rng.exp(mean_gap_us as f64),
            ArrivalModel::HeavyTail { scale_us, alpha } => {
                scale_us as f64 * (1.0 - rng.f64()).powf(-1.0 / alpha.max(0.1))
            }
        };
        now_us = now_us.saturating_add(gap as u64);
        let burst = match spec.arrivals {
            ArrivalModel::Bursty { burst, .. } => burst.max(1),
            _ => 1,
        };
        for b in 0..burst {
            let offset = match spec.arrivals {
                ArrivalModel::Bursty { within_us, .. } if b > 0 => rng.below(within_us.max(1)),
                _ => 0,
            };
            let tier = sample_tier(&spec.tiers, &mut rng);
            let t = &spec.tiers[tier];
            let mut prompt: Vec<i32> = Vec::new();
            if !prefixes.is_empty() && rng.bool(spec.shared_prefix_p) {
                prompt.extend_from_slice(rng.choose(&prefixes));
            }
            let fresh = spec.prompt_len.sample(&mut rng).max(1);
            prompt.extend((0..fresh).map(|_| token(&mut rng)));
            let mut arrival = now_us.saturating_add(offset);
            let mut turn = 0u32;
            loop {
                let max_tokens = spec.output_len.sample(&mut rng).max(1);
                events.push(TraceEvent {
                    id,
                    arrival_us: arrival,
                    session,
                    turn,
                    tier,
                    priority: t.priority,
                    ttft_deadline_ms: t.ttft_deadline_ms,
                    tpot_deadline_ms: t.tpot_deadline_ms,
                    prompt: prompt.clone(),
                    max_tokens,
                });
                id += 1;
                turn += 1;
                if turn as usize >= spec.max_turns || !rng.bool(spec.multi_turn_p) {
                    break;
                }
                // Follow-up: the conversation grows by a synthesized
                // response plus the user's next utterance, and arrives
                // after a think-time gap.
                prompt.extend((0..max_tokens).map(|_| token(&mut rng)));
                let next = spec.prompt_len.sample(&mut rng).max(1);
                prompt.extend((0..next).map(|_| token(&mut rng)));
                arrival = arrival
                    .saturating_add(rng.exp(spec.think_gap_us as f64) as u64);
            }
        }
    }
    events.sort_by_key(|e| (e.arrival_us, e.id));
    events
}

// ---------------------------------------------------------------------------
// JSONL fixtures
// ---------------------------------------------------------------------------

/// One JSON object per line, trailing newline, optional fields omitted
/// when absent. Keys render sorted (the JSON objects are BTreeMaps) and
/// every value is integral, so dump∘load is the identity on bytes.
pub fn dump_jsonl(events: &[TraceEvent]) -> String {
    let mut out = String::new();
    for e in events {
        let mut fields = vec![
            ("arrival_us", Json::num(e.arrival_us as f64)),
            ("id", Json::num(e.id as f64)),
            ("max_tokens", Json::num(e.max_tokens as f64)),
            ("priority", Json::num(e.priority as f64)),
            ("prompt", Json::arr(e.prompt.iter().map(|&t| Json::num(t as f64)))),
            ("session", Json::num(e.session as f64)),
            ("tier", Json::num(e.tier as f64)),
            ("turn", Json::num(e.turn as f64)),
        ];
        if let Some(ms) = e.ttft_deadline_ms {
            fields.push(("ttft_deadline_ms", Json::num(ms as f64)));
        }
        if let Some(ms) = e.tpot_deadline_ms {
            fields.push(("tpot_deadline_ms", Json::num(ms as f64)));
        }
        out.push_str(&Json::obj(fields).render());
        out.push('\n');
    }
    out
}

pub fn load_jsonl(text: &str) -> Result<Vec<TraceEvent>> {
    let mut events = Vec::new();
    for (lineno, line) in text.lines().enumerate() {
        let line = line.trim();
        if line.is_empty() {
            continue;
        }
        let j = Json::parse(line)
            .map_err(|e| anyhow!("trace line {}: bad json: {e}", lineno + 1))?;
        let req = |key: &str| {
            j.get(key)
                .and_then(Json::as_i64)
                .ok_or_else(|| anyhow!("trace line {}: missing {key:?}", lineno + 1))
        };
        let prompt = j
            .get("prompt")
            .and_then(Json::as_arr)
            .ok_or_else(|| anyhow!("trace line {}: missing \"prompt\"", lineno + 1))?
            .iter()
            .map(|v| {
                v.as_i64()
                    .map(|x| x as i32)
                    .ok_or_else(|| anyhow!("trace line {}: non-integer token", lineno + 1))
            })
            .collect::<Result<Vec<_>>>()?;
        events.push(TraceEvent {
            id: req("id")? as u64,
            arrival_us: req("arrival_us")? as u64,
            session: j.get("session").and_then(Json::as_i64).unwrap_or(0) as u64,
            turn: j.get("turn").and_then(Json::as_i64).unwrap_or(0) as u32,
            tier: j.get("tier").and_then(Json::as_usize).unwrap_or(0),
            priority: j.get("priority").and_then(Json::as_i64).unwrap_or(0) as i32,
            ttft_deadline_ms: j
                .get("ttft_deadline_ms")
                .and_then(Json::as_i64)
                .map(|v| v as u64),
            tpot_deadline_ms: j
                .get("tpot_deadline_ms")
                .and_then(Json::as_i64)
                .map(|v| v as u64),
            prompt,
            max_tokens: req("max_tokens")?.max(1) as usize,
        });
    }
    events.sort_by_key(|e| (e.arrival_us, e.id));
    Ok(events)
}

// ---------------------------------------------------------------------------
// Virtual-time replay
// ---------------------------------------------------------------------------

/// Replay knobs independent of the engine's own configuration.
#[derive(Debug, Clone, Copy)]
pub struct ReplayConfig {
    /// Overload ladder applied at the submission boundary (mirror of
    /// the TCP front door). Disabled by default.
    pub overload: OverloadPolicy,
    /// Virtual microseconds one engine iteration costs. The absolute
    /// value only scales the latency numbers; what matters is that it
    /// is fixed, so two replays of one fixture are bitwise identical.
    pub step_cost_us: u64,
    /// Base sampler seed (combined with each event id).
    pub seed: u64,
}

impl Default for ReplayConfig {
    fn default() -> Self {
        ReplayConfig { overload: OverloadPolicy::default(), step_cost_us: 1_000, seed: 0 }
    }
}

/// What happened to one trace event during a replay.
#[derive(Debug, Clone, PartialEq)]
pub struct ReplayOutcome {
    pub id: u64,
    pub tier: usize,
    /// false = shed by the overload ladder or rejected by the engine.
    pub admitted: bool,
    pub degraded: bool,
    pub tokens: Vec<i32>,
    pub ttft_us: u64,
    pub total_us: u64,
    /// Mean decode gap (total − ttft) / (tokens − 1), in µs.
    pub tpot_us: u64,
    pub met_slo: bool,
}

/// Per-tier goodput accounting.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct TierGoodput {
    pub tier: usize,
    pub total: usize,
    pub met: usize,
    pub shed: usize,
    pub degraded: usize,
}

impl TierGoodput {
    pub fn goodput(&self) -> f64 {
        if self.total == 0 {
            1.0
        } else {
            self.met as f64 / self.total as f64
        }
    }
}

#[derive(Debug, Clone)]
pub struct ReplayReport {
    /// One outcome per trace event, sorted by event id.
    pub outcomes: Vec<ReplayOutcome>,
    pub tiers: Vec<TierGoodput>,
    /// Virtual time at which the last request finished.
    pub makespan_us: u64,
}

impl ReplayReport {
    /// Overall goodput: fraction of all requests that were served and
    /// met every SLO they carried. A shed request never counts.
    pub fn goodput(&self) -> f64 {
        if self.outcomes.is_empty() {
            return 1.0;
        }
        let met = self.outcomes.iter().filter(|o| o.met_slo).count();
        met as f64 / self.outcomes.len() as f64
    }

    pub fn shed(&self) -> usize {
        self.outcomes.iter().filter(|o| !o.admitted).count()
    }

    pub fn degraded(&self) -> usize {
        self.outcomes.iter().filter(|o| o.degraded).count()
    }

    /// The `coordinator.slo` bench fragment for one (policy, trace) run.
    pub fn summary_json(&self) -> Json {
        Json::obj(vec![
            ("requests", Json::num(self.outcomes.len() as f64)),
            (
                "met",
                Json::num(self.outcomes.iter().filter(|o| o.met_slo).count() as f64),
            ),
            ("shed", Json::num(self.shed() as f64)),
            ("degraded", Json::num(self.degraded() as f64)),
            ("goodput", Json::num(self.goodput())),
            ("makespan_us", Json::num(self.makespan_us as f64)),
            (
                "tiers",
                Json::arr(self.tiers.iter().map(|t| {
                    Json::obj(vec![
                        ("tier", Json::num(t.tier as f64)),
                        ("total", Json::num(t.total as f64)),
                        ("met", Json::num(t.met as f64)),
                        ("shed", Json::num(t.shed as f64)),
                        ("degraded", Json::num(t.degraded as f64)),
                        ("goodput", Json::num(t.goodput())),
                    ])
                })),
            ),
        ])
    }
}

/// Drive `events` through `engine` on the virtual clock and score every
/// request against its SLOs.
///
/// The engine should be freshly built (policy and queue capacity are
/// the caller's choice); this function switches it to the virtual
/// clock. Admission order is strictly arrival order; the overload
/// ladder decides degrade/shed *before* submission, exactly like the
/// front door, so crash replays and re-runs see identical requests.
pub fn replay<M: StepModel>(
    engine: &mut InferenceEngine<M>,
    events: &[TraceEvent],
    cfg: &ReplayConfig,
) -> Result<ReplayReport> {
    engine.enable_virtual_clock();
    let n_tiers = events.iter().map(|e| e.tier + 1).max().unwrap_or(0);
    let mut tiers: Vec<TierGoodput> = (0..n_tiers)
        .map(|tier| TierGoodput { tier, total: 0, met: 0, shed: 0, degraded: 0 })
        .collect();
    // index into `events` → outcome slot; engine id → event index
    let mut outcomes: Vec<Option<ReplayOutcome>> = vec![None; events.len()];
    let mut by_request: HashMap<RequestId, usize> = HashMap::new();
    let mut ready: VecDeque<usize> = VecDeque::new();
    let mut next = 0usize;
    let mut makespan_us = 0u64;
    loop {
        let now = engine.now_us();
        while next < events.len() && events[next].arrival_us <= now {
            ready.push_back(next);
            next += 1;
        }
        // Admit in arrival order until the engine pushes back.
        while let Some(&i) = ready.front() {
            let e = &events[i];
            let mut params = e.params(cfg.seed);
            if cfg.overload.enabled() {
                match cfg.overload.action(engine.queue_pressure(), params.priority) {
                    OverloadAction::Admit => {}
                    OverloadAction::Degrade => params.degrade = true,
                    OverloadAction::Shed => {
                        tiers[e.tier].total += 1;
                        tiers[e.tier].shed += 1;
                        outcomes[i] = Some(ReplayOutcome {
                            id: e.id,
                            tier: e.tier,
                            admitted: false,
                            degraded: false,
                            tokens: Vec::new(),
                            ttft_us: 0,
                            total_us: 0,
                            tpot_us: 0,
                            met_slo: false,
                        });
                        ready.pop_front();
                        continue;
                    }
                }
            }
            match engine.try_submit(e.prompt.clone(), params) {
                Ok(id) => {
                    by_request.insert(id, i);
                    ready.pop_front();
                }
                Err(SubmitError::Backpressure { .. }) => break, // full: retry after a step
                Err(SubmitError::Invalid(_)) => {
                    tiers[e.tier].total += 1;
                    tiers[e.tier].shed += 1;
                    outcomes[i] = Some(ReplayOutcome {
                        id: e.id,
                        tier: e.tier,
                        admitted: false,
                        degraded: false,
                        tokens: Vec::new(),
                        ttft_us: 0,
                        total_us: 0,
                        tpot_us: 0,
                        met_slo: false,
                    });
                    ready.pop_front();
                }
            }
        }
        if engine.is_idle() && ready.is_empty() {
            if next >= events.len() {
                break;
            }
            // Nothing to do until the next arrival: jump straight there.
            engine.advance_clock_us(events[next].arrival_us - engine.now_us());
            continue;
        }
        // Charge the step *before* executing it: a token computed by
        // this iteration becomes visible at its end, so even a
        // single-chunk prefill pays one step of TTFT.
        engine.advance_clock_us(cfg.step_cost_us);
        engine.step()?;
        for c in engine.take_completions() {
            let Some(i) = by_request.remove(&c.id) else { continue };
            let e = &events[i];
            let ttft_us = c.ttft_us.unwrap_or(0);
            let total_us = c.total_us.unwrap_or(ttft_us);
            let tpot_us =
                total_us.saturating_sub(ttft_us) / (c.tokens.len().max(2) as u64 - 1);
            let ttft_ok = e
                .ttft_deadline_ms
                .is_none_or(|ms| ttft_us <= ms.saturating_mul(1000));
            let tpot_ok = e
                .tpot_deadline_ms
                .is_none_or(|ms| tpot_us <= ms.saturating_mul(1000));
            let met_slo = ttft_ok && tpot_ok;
            tiers[e.tier].total += 1;
            if met_slo {
                tiers[e.tier].met += 1;
            }
            if c.degraded {
                tiers[e.tier].degraded += 1;
            }
            makespan_us = makespan_us.max(engine.now_us());
            outcomes[i] = Some(ReplayOutcome {
                id: e.id,
                tier: e.tier,
                admitted: true,
                degraded: c.degraded,
                tokens: c.tokens,
                ttft_us,
                total_us,
                tpot_us,
                met_slo,
            });
        }
    }
    let outcomes: Vec<ReplayOutcome> = outcomes
        .into_iter()
        .enumerate()
        .map(|(i, o)| o.ok_or_else(|| anyhow!("event {} never resolved", events[i].id)))
        .collect::<Result<_>>()?;
    Ok(ReplayReport { outcomes, tiers, makespan_us })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::engine_loop::EngineConfig;
    use crate::coordinator::model::MockModel;
    use crate::coordinator::scheduler::PolicyKind;

    fn engine(policy: PolicyKind, queue_cap: usize) -> InferenceEngine<MockModel> {
        let mut cfg = EngineConfig { queue_capacity: queue_cap, ..Default::default() };
        cfg.scheduler.policy = policy;
        InferenceEngine::new(MockModel::new(2, 96, 64, vec![4, 8]), cfg)
    }

    fn small_spec() -> TraceSpec {
        TraceSpec {
            seed: 7,
            sessions: 10,
            prompt_len: LengthModel::Uniform { lo: 2, hi: 10 },
            output_len: LengthModel::Uniform { lo: 1, hi: 4 },
            vocab: 64,
            tiers: vec![
                TierSpec {
                    weight: 1.0,
                    priority: 0,
                    ttft_deadline_ms: None,
                    tpot_deadline_ms: None,
                },
                TierSpec {
                    weight: 1.0,
                    priority: 1,
                    ttft_deadline_ms: Some(50),
                    tpot_deadline_ms: Some(30),
                },
            ],
            ..Default::default()
        }
    }

    #[test]
    fn generation_is_deterministic_and_sorted() {
        let spec = small_spec();
        let a = generate(&spec);
        let b = generate(&spec);
        assert_eq!(a, b);
        assert!(a.len() >= spec.sessions);
        assert!(a.windows(2).all(|w| w[0].arrival_us <= w[1].arrival_us));
        let mut ids: Vec<u64> = a.iter().map(|e| e.id).collect();
        ids.sort_unstable();
        ids.dedup();
        assert_eq!(ids.len(), a.len(), "duplicate event ids");
        // a different seed gives a different trace
        let c = generate(&TraceSpec { seed: 8, ..spec });
        assert_ne!(a, c);
    }

    #[test]
    fn multi_turn_prompts_grow_from_previous_turn() {
        let spec = TraceSpec { multi_turn_p: 1.0, max_turns: 3, ..small_spec() };
        let events = generate(&spec);
        let mut by_session: HashMap<u64, Vec<&TraceEvent>> = HashMap::new();
        for e in &events {
            by_session.entry(e.session).or_default().push(e);
        }
        let mut saw_followup = false;
        for turns in by_session.values_mut() {
            turns.sort_by_key(|e| e.turn);
            for w in turns.windows(2) {
                saw_followup = true;
                assert!(w[1].arrival_us > w[0].arrival_us, "turns move forward in time");
                assert!(
                    w[1].prompt.starts_with(&w[0].prompt),
                    "turn {} must extend turn {}'s prompt",
                    w[1].turn,
                    w[0].turn
                );
            }
        }
        assert!(saw_followup, "p=1.0 must produce follow-up turns");
    }

    #[test]
    fn jsonl_round_trip_is_bitwise() {
        let events = generate(&small_spec());
        let dumped = dump_jsonl(&events);
        let loaded = load_jsonl(&dumped).unwrap();
        assert_eq!(loaded, events, "load(dump(t)) == t");
        assert_eq!(dump_jsonl(&loaded), dumped, "dump(load(d)) == d, bitwise");
    }

    #[test]
    fn load_rejects_garbage_and_tolerates_blank_lines() {
        assert!(load_jsonl("{\"id\":1}\n").is_err(), "missing fields");
        assert!(load_jsonl("not json\n").is_err());
        let ok = load_jsonl(
            "\n{\"arrival_us\":5,\"id\":0,\"max_tokens\":2,\"prompt\":[1,2]}\n\n",
        )
        .unwrap();
        assert_eq!(ok.len(), 1);
        assert_eq!(ok[0].tier, 0, "tier defaults to 0");
        assert_eq!(ok[0].ttft_deadline_ms, None, "no deadline when absent");
    }

    #[test]
    fn replay_is_bitwise_deterministic() {
        let events = generate(&small_spec());
        let cfg = ReplayConfig { step_cost_us: 700, ..Default::default() };
        for policy in PolicyKind::all() {
            let a = replay(&mut engine(policy, 64), &events, &cfg).unwrap();
            let b = replay(&mut engine(policy, 64), &events, &cfg).unwrap();
            assert_eq!(a.outcomes, b.outcomes, "{policy:?} replay must be bitwise");
            assert_eq!(a.goodput(), b.goodput());
            assert_eq!(a.outcomes.len(), events.len());
            assert!(a.outcomes.iter().all(|o| o.admitted), "no overload configured");
        }
    }

    #[test]
    fn replay_policies_agree_on_streams_but_not_order() {
        // Policies only permute admission: every request's token stream
        // is identical across policies even though latencies differ.
        let events = generate(&small_spec());
        let cfg = ReplayConfig::default();
        let fifo = replay(&mut engine(PolicyKind::Fifo, 64), &events, &cfg).unwrap();
        let edf = replay(&mut engine(PolicyKind::Edf, 64), &events, &cfg).unwrap();
        for (f, e) in fifo.outcomes.iter().zip(&edf.outcomes) {
            assert_eq!(f.id, e.id);
            assert_eq!(f.tokens, e.tokens, "streams are policy-invariant");
        }
    }

    #[test]
    fn overload_ladder_sheds_and_degrades_in_replay() {
        // A tiny queue under a burst: the bulk tier degrades, then
        // sheds; the interactive tier (priority 1 > tier_max 0) never
        // does either.
        let spec = TraceSpec {
            arrivals: ArrivalModel::Bursty { mean_gap_us: 20_000, burst: 8, within_us: 100 },
            sessions: 4,
            multi_turn_p: 0.0,
            ..small_spec()
        };
        let events = generate(&spec);
        let cfg = ReplayConfig {
            overload: OverloadPolicy { degrade_at: 0.25, shed_at: 0.75, tier_max: 0 },
            step_cost_us: 2_000,
            seed: 0,
        };
        let report = replay(&mut engine(PolicyKind::Fifo, 8), &events, &cfg).unwrap();
        assert!(report.degraded() > 0, "burst must trigger degradation");
        for o in &report.outcomes {
            let tier = &spec.tiers[o.tier];
            if tier.priority > 0 {
                assert!(o.admitted, "high tier must never shed");
                assert!(!o.degraded, "high tier must never degrade");
            }
        }
        let shed_plus_served: usize = report.tiers.iter().map(|t| t.total).sum();
        assert_eq!(shed_plus_served, events.len(), "every event accounted");
    }

    #[test]
    fn goodput_scores_deadlines() {
        // step_cost large enough that the tight tier cannot make TTFT.
        let spec = TraceSpec {
            tiers: vec![TierSpec {
                weight: 1.0,
                priority: 0,
                ttft_deadline_ms: Some(1),
                tpot_deadline_ms: None,
            }],
            sessions: 4,
            multi_turn_p: 0.0,
            ..small_spec()
        };
        let events = generate(&spec);
        let cfg = ReplayConfig { step_cost_us: 5_000, ..Default::default() };
        let strict = replay(&mut engine(PolicyKind::Fifo, 64), &events, &cfg).unwrap();
        assert!(strict.goodput() < 1.0, "1ms TTFT at 5ms/step must miss");
        // the same trace with no deadlines scores perfectly
        let relaxed: Vec<TraceEvent> = events
            .iter()
            .map(|e| TraceEvent { ttft_deadline_ms: None, tpot_deadline_ms: None, ..e.clone() })
            .collect();
        let free = replay(&mut engine(PolicyKind::Fifo, 64), &relaxed, &cfg).unwrap();
        assert_eq!(free.goodput(), 1.0);
        assert_eq!(free.tiers[0].met, free.tiers[0].total);
    }

    #[test]
    fn summary_json_carries_tier_breakdown() {
        let events = generate(&small_spec());
        let report =
            replay(&mut engine(PolicyKind::Edf, 64), &events, &ReplayConfig::default())
                .unwrap();
        let j = report.summary_json();
        assert_eq!(
            j.get("requests").and_then(Json::as_usize),
            Some(events.len())
        );
        let tiers = j.get("tiers").and_then(Json::as_arr).unwrap();
        assert_eq!(tiers.len(), report.tiers.len());
        let total: usize = tiers
            .iter()
            .map(|t| t.get("total").and_then(Json::as_usize).unwrap())
            .sum();
        assert_eq!(total, events.len());
        assert!(j.get("goodput").and_then(Json::as_f64).is_some());
    }
}
