//! Minimal argument parser (no `clap` in the offline vendor set).
//!
//! Supports subcommands, `--flag`, `--key value` / `--key=value`, and
//! positional arguments, with typed accessors and generated usage text.

use std::collections::BTreeMap;

#[derive(Debug, Clone, Default)]
pub struct Args {
    pub subcommand: Option<String>,
    pub flags: BTreeMap<String, String>,
    pub positional: Vec<String>,
}

#[derive(Debug, Clone, PartialEq)]
pub struct CliError(pub String);

impl std::fmt::Display for CliError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "{}", self.0)
    }
}

impl std::error::Error for CliError {}

impl Args {
    /// Parse raw argv (without the program name). `with_subcommand`
    /// treats the first bare word as a subcommand.
    pub fn parse<I, S>(argv: I, with_subcommand: bool) -> Result<Args, CliError>
    where
        I: IntoIterator<Item = S>,
        S: Into<String>,
    {
        let mut out = Args::default();
        let mut it = argv.into_iter().map(|s| s.into()).peekable();
        while let Some(arg) = it.next() {
            if let Some(stripped) = arg.strip_prefix("--") {
                if stripped.is_empty() {
                    // `--` ends flag parsing
                    out.positional.extend(it);
                    break;
                }
                if let Some((k, v)) = stripped.split_once('=') {
                    out.flags.insert(k.to_string(), v.to_string());
                } else {
                    // value if the next token isn't a flag; else boolean
                    match it.peek() {
                        Some(nxt) if !nxt.starts_with("--") => {
                            let v = it.next().unwrap();
                            out.flags.insert(stripped.to_string(), v);
                        }
                        _ => {
                            out.flags.insert(stripped.to_string(), "true".into());
                        }
                    }
                }
            } else if with_subcommand && out.subcommand.is_none() && out.positional.is_empty() {
                out.subcommand = Some(arg);
            } else {
                out.positional.push(arg);
            }
        }
        Ok(out)
    }

    pub fn from_env(with_subcommand: bool) -> Result<Args, CliError> {
        Args::parse(std::env::args().skip(1), with_subcommand)
    }

    pub fn str(&self, key: &str, default: &str) -> String {
        self.flags.get(key).cloned().unwrap_or_else(|| default.to_string())
    }

    pub fn opt_str(&self, key: &str) -> Option<String> {
        self.flags.get(key).cloned()
    }

    pub fn usize(&self, key: &str, default: usize) -> Result<usize, CliError> {
        match self.flags.get(key) {
            None => Ok(default),
            Some(v) => v
                .parse()
                .map_err(|_| CliError(format!("--{key} expects an integer, got {v:?}"))),
        }
    }

    pub fn f64(&self, key: &str, default: f64) -> Result<f64, CliError> {
        match self.flags.get(key) {
            None => Ok(default),
            Some(v) => v
                .parse()
                .map_err(|_| CliError(format!("--{key} expects a number, got {v:?}"))),
        }
    }

    pub fn bool(&self, key: &str) -> bool {
        matches!(self.flags.get(key).map(|s| s.as_str()), Some("true") | Some("1"))
    }

    /// Comma-separated list flag.
    pub fn list(&self, key: &str, default: &[&str]) -> Vec<String> {
        match self.flags.get(key) {
            Some(v) => v.split(',').map(|s| s.trim().to_string()).collect(),
            None => default.iter().map(|s| s.to_string()).collect(),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn p(args: &[&str]) -> Args {
        Args::parse(args.iter().copied(), true).unwrap()
    }

    #[test]
    fn subcommand_and_flags() {
        let a = p(&["serve", "--port", "8080", "--verbose", "--name=x"]);
        assert_eq!(a.subcommand.as_deref(), Some("serve"));
        assert_eq!(a.usize("port", 0).unwrap(), 8080);
        assert!(a.bool("verbose"));
        assert_eq!(a.str("name", ""), "x");
    }

    #[test]
    fn positional_after_double_dash() {
        let a = p(&["run", "--x", "1", "--", "--not-a-flag"]);
        assert_eq!(a.positional, vec!["--not-a-flag"]);
    }

    #[test]
    fn defaults() {
        let a = p(&["cmd"]);
        assert_eq!(a.usize("missing", 7).unwrap(), 7);
        assert_eq!(a.f64("missing", 1.5).unwrap(), 1.5);
        assert!(!a.bool("missing"));
        assert_eq!(a.list("ratios", &["a", "b"]), vec!["a", "b"]);
    }

    #[test]
    fn list_parsing() {
        let a = p(&["cmd", "--ratios", "50,70,80"]);
        assert_eq!(a.list("ratios", &[]), vec!["50", "70", "80"]);
    }

    #[test]
    fn bad_number_errors() {
        let a = p(&["cmd", "--n", "abc"]);
        assert!(a.usize("n", 0).is_err());
    }
}
