//! From-scratch JSON parser/serializer.
//!
//! The offline vendor set has no `serde`, so the manifest loader and the
//! line-delimited-JSON server protocol use this minimal but complete
//! implementation (objects, arrays, strings with escapes, numbers, bools,
//! null; serialization with stable key order for deterministic tests).

use std::collections::BTreeMap;
use std::fmt;

#[derive(Debug, Clone, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

#[derive(Debug, Clone, PartialEq)]
pub struct JsonError {
    pub msg: String,
    pub pos: usize,
}

impl fmt::Display for JsonError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "json error at byte {}: {}", self.pos, self.msg)
    }
}

impl std::error::Error for JsonError {}

impl Json {
    pub fn parse(s: &str) -> Result<Json, JsonError> {
        let mut p = Parser { b: s.as_bytes(), i: 0 };
        p.ws();
        let v = p.value()?;
        p.ws();
        if p.i != p.b.len() {
            return Err(p.err("trailing data"));
        }
        Ok(v)
    }

    // -- typed accessors ---------------------------------------------------

    pub fn get(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(m) => m.get(key),
            _ => None,
        }
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(n) => Some(*n),
            _ => None,
        }
    }

    pub fn as_i64(&self) -> Option<i64> {
        self.as_f64().map(|n| n as i64)
    }

    pub fn as_usize(&self) -> Option<usize> {
        self.as_f64()
            .and_then(|n| if n >= 0.0 { Some(n as usize) } else { None })
    }

    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Json::Bool(b) => Some(*b),
            _ => None,
        }
    }

    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(a) => Some(a),
            _ => None,
        }
    }

    pub fn as_obj(&self) -> Option<&BTreeMap<String, Json>> {
        match self {
            Json::Obj(m) => Some(m),
            _ => None,
        }
    }

    /// `obj.path("a.b.c")` — dotted lookup, None on any miss.
    pub fn path(&self, dotted: &str) -> Option<&Json> {
        let mut cur = self;
        for part in dotted.split('.') {
            cur = cur.get(part)?;
        }
        Some(cur)
    }

    // -- constructors for serialization ------------------------------------

    pub fn obj(pairs: Vec<(&str, Json)>) -> Json {
        Json::Obj(pairs.into_iter().map(|(k, v)| (k.to_string(), v)).collect())
    }

    pub fn arr<I: IntoIterator<Item = Json>>(items: I) -> Json {
        Json::Arr(items.into_iter().collect())
    }

    pub fn num<T: Into<f64>>(n: T) -> Json {
        Json::Num(n.into())
    }

    pub fn str(s: &str) -> Json {
        Json::Str(s.to_string())
    }

    pub fn render(&self) -> String {
        let mut out = String::new();
        self.write(&mut out);
        out
    }

    fn write(&self, out: &mut String) {
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(true) => out.push_str("true"),
            Json::Bool(false) => out.push_str("false"),
            Json::Num(n) => {
                if n.fract() == 0.0 && n.abs() < 9.0e15 {
                    out.push_str(&format!("{}", *n as i64));
                } else {
                    out.push_str(&format!("{}", n));
                }
            }
            Json::Str(s) => write_escaped(s, out),
            Json::Arr(a) => {
                out.push('[');
                for (i, v) in a.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    v.write(out);
                }
                out.push(']');
            }
            Json::Obj(m) => {
                out.push('{');
                for (i, (k, v)) in m.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    write_escaped(k, out);
                    out.push(':');
                    v.write(out);
                }
                out.push('}');
            }
        }
    }
}

impl fmt::Display for Json {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.render())
    }
}

fn write_escaped(s: &str, out: &mut String) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => out.push_str(&format!("\\u{:04x}", c as u32)),
            c => out.push(c),
        }
    }
    out.push('"');
}

struct Parser<'a> {
    b: &'a [u8],
    i: usize,
}

impl<'a> Parser<'a> {
    fn err(&self, msg: &str) -> JsonError {
        JsonError { msg: msg.to_string(), pos: self.i }
    }

    fn ws(&mut self) {
        while self.i < self.b.len()
            && matches!(self.b[self.i], b' ' | b'\t' | b'\n' | b'\r')
        {
            self.i += 1;
        }
    }

    fn peek(&self) -> Option<u8> {
        self.b.get(self.i).copied()
    }

    fn expect(&mut self, c: u8) -> Result<(), JsonError> {
        if self.peek() == Some(c) {
            self.i += 1;
            Ok(())
        } else {
            Err(self.err(&format!("expected '{}'", c as char)))
        }
    }

    fn value(&mut self) -> Result<Json, JsonError> {
        match self.peek() {
            Some(b'{') => self.object(),
            Some(b'[') => self.array(),
            Some(b'"') => Ok(Json::Str(self.string()?)),
            Some(b't') => self.lit("true", Json::Bool(true)),
            Some(b'f') => self.lit("false", Json::Bool(false)),
            Some(b'n') => self.lit("null", Json::Null),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            _ => Err(self.err("expected a value")),
        }
    }

    fn lit(&mut self, word: &str, v: Json) -> Result<Json, JsonError> {
        if self.b[self.i..].starts_with(word.as_bytes()) {
            self.i += word.len();
            Ok(v)
        } else {
            Err(self.err(&format!("expected '{}'", word)))
        }
    }

    fn number(&mut self) -> Result<Json, JsonError> {
        let start = self.i;
        if self.peek() == Some(b'-') {
            self.i += 1;
        }
        while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
            self.i += 1;
        }
        if self.peek() == Some(b'.') {
            self.i += 1;
            while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
                self.i += 1;
            }
        }
        if matches!(self.peek(), Some(b'e') | Some(b'E')) {
            self.i += 1;
            if matches!(self.peek(), Some(b'+') | Some(b'-')) {
                self.i += 1;
            }
            while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
                self.i += 1;
            }
        }
        let text = std::str::from_utf8(&self.b[start..self.i]).unwrap();
        text.parse::<f64>().map(Json::Num).map_err(|_| self.err("bad number"))
    }

    fn string(&mut self) -> Result<String, JsonError> {
        self.expect(b'"')?;
        let mut out = String::new();
        loop {
            match self.peek() {
                None => return Err(self.err("unterminated string")),
                Some(b'"') => {
                    self.i += 1;
                    return Ok(out);
                }
                Some(b'\\') => {
                    self.i += 1;
                    match self.peek() {
                        Some(b'"') => out.push('"'),
                        Some(b'\\') => out.push('\\'),
                        Some(b'/') => out.push('/'),
                        Some(b'n') => out.push('\n'),
                        Some(b't') => out.push('\t'),
                        Some(b'r') => out.push('\r'),
                        Some(b'b') => out.push('\u{8}'),
                        Some(b'f') => out.push('\u{c}'),
                        Some(b'u') => {
                            if self.i + 4 >= self.b.len() {
                                return Err(self.err("bad \\u escape"));
                            }
                            let hex = std::str::from_utf8(
                                &self.b[self.i + 1..self.i + 5],
                            )
                            .map_err(|_| self.err("bad \\u escape"))?;
                            let cp = u32::from_str_radix(hex, 16)
                                .map_err(|_| self.err("bad \\u escape"))?;
                            out.push(char::from_u32(cp).unwrap_or('\u{fffd}'));
                            self.i += 4;
                        }
                        _ => return Err(self.err("bad escape")),
                    }
                    self.i += 1;
                }
                Some(_) => {
                    // copy a full utf-8 scalar
                    let s = std::str::from_utf8(&self.b[self.i..])
                        .map_err(|_| self.err("invalid utf-8"))?;
                    let c = s.chars().next().unwrap();
                    out.push(c);
                    self.i += c.len_utf8();
                }
            }
        }
    }

    fn array(&mut self) -> Result<Json, JsonError> {
        self.expect(b'[')?;
        let mut items = Vec::new();
        self.ws();
        if self.peek() == Some(b']') {
            self.i += 1;
            return Ok(Json::Arr(items));
        }
        loop {
            self.ws();
            items.push(self.value()?);
            self.ws();
            match self.peek() {
                Some(b',') => self.i += 1,
                Some(b']') => {
                    self.i += 1;
                    return Ok(Json::Arr(items));
                }
                _ => return Err(self.err("expected ',' or ']'")),
            }
        }
    }

    fn object(&mut self) -> Result<Json, JsonError> {
        self.expect(b'{')?;
        let mut map = BTreeMap::new();
        self.ws();
        if self.peek() == Some(b'}') {
            self.i += 1;
            return Ok(Json::Obj(map));
        }
        loop {
            self.ws();
            let key = self.string()?;
            self.ws();
            self.expect(b':')?;
            self.ws();
            let val = self.value()?;
            map.insert(key, val);
            self.ws();
            match self.peek() {
                Some(b',') => self.i += 1,
                Some(b'}') => {
                    self.i += 1;
                    return Ok(Json::Obj(map));
                }
                _ => return Err(self.err("expected ',' or '}'")),
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_scalars() {
        assert_eq!(Json::parse("null").unwrap(), Json::Null);
        assert_eq!(Json::parse("true").unwrap(), Json::Bool(true));
        assert_eq!(Json::parse("-3.5e2").unwrap(), Json::Num(-350.0));
        assert_eq!(Json::parse("\"a\\nb\"").unwrap(), Json::Str("a\nb".into()));
    }

    #[test]
    fn parses_nested() {
        let v = Json::parse(r#"{"a": [1, 2, {"b": "x"}], "c": false}"#).unwrap();
        assert_eq!(v.path("a").unwrap().as_arr().unwrap().len(), 3);
        assert_eq!(v.path("c").unwrap().as_bool(), Some(false));
        assert_eq!(
            v.path("a").unwrap().as_arr().unwrap()[2].path("b").unwrap().as_str(),
            Some("x")
        );
    }

    #[test]
    fn rejects_garbage() {
        assert!(Json::parse("{").is_err());
        assert!(Json::parse("[1,]").is_err());
        assert!(Json::parse("1 2").is_err());
        assert!(Json::parse("\"unterminated").is_err());
    }

    #[test]
    fn roundtrips() {
        let src = r#"{"arr":[1,2.5,"s"],"nested":{"k":null},"t":true}"#;
        let v = Json::parse(src).unwrap();
        let re = Json::parse(&v.render()).unwrap();
        assert_eq!(v, re);
    }

    #[test]
    fn escapes_roundtrip() {
        let v = Json::Str("tab\there \"q\" \\ nl\n".into());
        assert_eq!(Json::parse(&v.render()).unwrap(), v);
    }

    #[test]
    fn deep_paths() {
        let v = Json::parse(r#"{"a":{"b":{"c":42}}}"#).unwrap();
        assert_eq!(v.path("a.b.c").unwrap().as_i64(), Some(42));
        assert!(v.path("a.b.x").is_none());
    }
}
