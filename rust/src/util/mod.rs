//! std-only substrates: JSON, CLI parsing, RNG, statistics, thread pool.

pub mod cli;
pub mod json;
pub mod rng;
pub mod stats;
pub mod threadpool;
