//! Deterministic RNG (SplitMix64 + xoshiro256**), std-only.
//!
//! Used by the workload generators, the sampler's stochastic modes, and
//! the proptest-lite harness. Seeded explicitly everywhere — benches and
//! tests are reproducible by construction.

#[derive(Debug, Clone)]
pub struct Rng {
    s: [u64; 4],
}

fn splitmix64(state: &mut u64) -> u64 {
    *state = state.wrapping_add(0x9E37_79B9_7F4A_7C15);
    let mut z = *state;
    z = (z ^ (z >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
    z ^ (z >> 31)
}

impl Rng {
    pub fn new(seed: u64) -> Self {
        let mut sm = seed;
        Rng {
            s: [
                splitmix64(&mut sm),
                splitmix64(&mut sm),
                splitmix64(&mut sm),
                splitmix64(&mut sm),
            ],
        }
    }

    /// Derive an independent stream (for per-request / per-worker rngs).
    pub fn fork(&mut self, tag: u64) -> Rng {
        Rng::new(self.next_u64() ^ tag.wrapping_mul(0x9E37_79B9_7F4A_7C15))
    }

    pub fn next_u64(&mut self) -> u64 {
        let r = self.s[1].wrapping_mul(5).rotate_left(7).wrapping_mul(9);
        let t = self.s[1] << 17;
        self.s[2] ^= self.s[0];
        self.s[3] ^= self.s[1];
        self.s[1] ^= self.s[2];
        self.s[0] ^= self.s[3];
        self.s[2] ^= t;
        self.s[3] = self.s[3].rotate_left(45);
        r
    }

    /// Uniform in [0, n). Unbiased via rejection.
    pub fn below(&mut self, n: u64) -> u64 {
        assert!(n > 0);
        let zone = u64::MAX - (u64::MAX % n);
        loop {
            let v = self.next_u64();
            if v < zone {
                return v % n;
            }
        }
    }

    /// Uniform in [lo, hi] inclusive.
    pub fn range_u64(&mut self, lo: u64, hi: u64) -> u64 {
        assert!(lo <= hi);
        lo + self.below(hi - lo + 1)
    }

    pub fn usize_below(&mut self, n: usize) -> usize {
        self.below(n as u64) as usize
    }

    /// Uniform f64 in [0, 1).
    pub fn f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    pub fn f32(&mut self) -> f32 {
        self.f64() as f32
    }

    /// Standard normal (Box-Muller).
    pub fn normal(&mut self) -> f64 {
        let u1 = self.f64().max(1e-300);
        let u2 = self.f64();
        (-2.0 * u1.ln()).sqrt() * (2.0 * std::f64::consts::PI * u2).cos()
    }

    /// Exponential with mean `mean` (Poisson inter-arrival times).
    pub fn exp(&mut self, mean: f64) -> f64 {
        -mean * (1.0 - self.f64()).ln()
    }

    pub fn bool(&mut self, p: f64) -> bool {
        self.f64() < p
    }

    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.usize_below(i + 1);
            xs.swap(i, j);
        }
    }

    pub fn choose<'a, T>(&mut self, xs: &'a [T]) -> &'a T {
        &xs[self.usize_below(xs.len())]
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic() {
        let mut a = Rng::new(42);
        let mut b = Rng::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn seeds_differ() {
        let mut a = Rng::new(1);
        let mut b = Rng::new(2);
        assert_ne!(
            (0..8).map(|_| a.next_u64()).collect::<Vec<_>>(),
            (0..8).map(|_| b.next_u64()).collect::<Vec<_>>()
        );
    }

    #[test]
    fn below_in_range_and_covers() {
        let mut r = Rng::new(7);
        let mut seen = [false; 10];
        for _ in 0..1000 {
            let v = r.below(10) as usize;
            assert!(v < 10);
            seen[v] = true;
        }
        assert!(seen.iter().all(|&s| s));
    }

    #[test]
    fn f64_unit_interval() {
        let mut r = Rng::new(3);
        let mut sum = 0.0;
        for _ in 0..10_000 {
            let v = r.f64();
            assert!((0.0..1.0).contains(&v));
            sum += v;
        }
        let mean = sum / 10_000.0;
        assert!((mean - 0.5).abs() < 0.02, "mean {}", mean);
    }

    #[test]
    fn normal_moments() {
        let mut r = Rng::new(9);
        let n = 20_000;
        let (mut s, mut s2) = (0.0, 0.0);
        for _ in 0..n {
            let v = r.normal();
            s += v;
            s2 += v * v;
        }
        let mean = s / n as f64;
        let var = s2 / n as f64 - mean * mean;
        assert!(mean.abs() < 0.05, "mean {}", mean);
        assert!((var - 1.0).abs() < 0.1, "var {}", var);
    }

    #[test]
    fn shuffle_permutes() {
        let mut r = Rng::new(5);
        let mut v: Vec<u32> = (0..50).collect();
        r.shuffle(&mut v);
        let mut sorted = v.clone();
        sorted.sort();
        assert_eq!(sorted, (0..50).collect::<Vec<_>>());
        assert_ne!(v, sorted); // astronomically unlikely to be identity
    }
}
