//! Latency/throughput statistics: streaming summaries and percentile
//! estimation for the serving metrics and the bench harness.

/// A simple reservoir of raw samples with summary queries. For the scales
/// this repo benches (<= millions of samples) exact percentiles are fine.
#[derive(Debug, Clone, Default)]
pub struct Samples {
    xs: Vec<f64>,
    sorted: bool,
}

impl Samples {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn push(&mut self, x: f64) {
        self.xs.push(x);
        self.sorted = false;
    }

    pub fn len(&self) -> usize {
        self.xs.len()
    }

    pub fn is_empty(&self) -> bool {
        self.xs.is_empty()
    }

    pub fn mean(&self) -> f64 {
        if self.xs.is_empty() {
            return f64::NAN;
        }
        self.xs.iter().sum::<f64>() / self.xs.len() as f64
    }

    pub fn stddev(&self) -> f64 {
        let n = self.xs.len();
        if n < 2 {
            return 0.0;
        }
        let m = self.mean();
        (self.xs.iter().map(|x| (x - m) * (x - m)).sum::<f64>()
            / (n - 1) as f64)
            .sqrt()
    }

    fn ensure_sorted(&mut self) {
        if !self.sorted {
            // total_cmp is NaN-safe (NaNs sort to the ends) where
            // partial_cmp().unwrap() would panic on the first NaN sample.
            self.xs.sort_by(f64::total_cmp);
            self.sorted = true;
        }
    }

    /// Linear-interpolated percentile, q in [0, 100].
    pub fn percentile(&mut self, q: f64) -> f64 {
        if self.xs.is_empty() {
            return f64::NAN;
        }
        self.ensure_sorted();
        let n = self.xs.len();
        let rank = (q / 100.0) * (n - 1) as f64;
        let lo = rank.floor() as usize;
        let hi = rank.ceil() as usize;
        if lo == hi {
            self.xs[lo]
        } else {
            let w = rank - lo as f64;
            self.xs[lo] * (1.0 - w) + self.xs[hi] * w
        }
    }

    pub fn min(&mut self) -> f64 {
        self.percentile(0.0)
    }

    pub fn max(&mut self) -> f64 {
        self.percentile(100.0)
    }

    pub fn summary(&mut self) -> Summary {
        Summary {
            n: self.len(),
            mean: self.mean(),
            stddev: self.stddev(),
            min: self.min(),
            p50: self.percentile(50.0),
            p90: self.percentile(90.0),
            p99: self.percentile(99.0),
            max: self.max(),
        }
    }
}

#[derive(Debug, Clone, Copy, PartialEq)]
pub struct Summary {
    pub n: usize,
    pub mean: f64,
    pub stddev: f64,
    pub min: f64,
    pub p50: f64,
    pub p90: f64,
    pub p99: f64,
    pub max: f64,
}

impl std::fmt::Display for Summary {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(
            f,
            "n={} mean={:.3} sd={:.3} min={:.3} p50={:.3} p90={:.3} p99={:.3} max={:.3}",
            self.n, self.mean, self.stddev, self.min, self.p50, self.p90,
            self.p99, self.max
        )
    }
}

/// Fixed-bucket histogram (log-spaced) for cheap streaming distributions.
#[derive(Debug, Clone)]
pub struct LogHistogram {
    /// bucket i covers [base * 2^i, base * 2^(i+1))
    base: f64,
    counts: Vec<u64>,
    underflow: u64,
    total: u64,
}

impl LogHistogram {
    pub fn new(base: f64, buckets: usize) -> Self {
        assert!(base > 0.0 && buckets > 0);
        LogHistogram { base, counts: vec![0; buckets], underflow: 0, total: 0 }
    }

    pub fn record(&mut self, x: f64) {
        self.total += 1;
        if x < self.base {
            self.underflow += 1;
            return;
        }
        let idx = ((x / self.base).log2().floor() as usize)
            .min(self.counts.len() - 1);
        self.counts[idx] += 1;
    }

    pub fn total(&self) -> u64 {
        self.total
    }

    /// Upper bound of the bucket holding quantile q (conservative).
    pub fn quantile_upper(&self, q: f64) -> f64 {
        if self.total == 0 {
            return f64::NAN;
        }
        let target = (q * self.total as f64).ceil() as u64;
        let mut seen = self.underflow;
        if seen >= target {
            return self.base;
        }
        for (i, c) in self.counts.iter().enumerate() {
            seen += c;
            if seen >= target {
                return self.base * 2f64.powi(i as i32 + 1);
            }
        }
        f64::INFINITY
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn mean_and_percentiles() {
        let mut s = Samples::new();
        for i in 1..=100 {
            s.push(i as f64);
        }
        assert!((s.mean() - 50.5).abs() < 1e-9);
        assert!((s.percentile(50.0) - 50.5).abs() < 1e-9);
        assert_eq!(s.min(), 1.0);
        assert_eq!(s.max(), 100.0);
        assert!((s.percentile(99.0) - 99.01).abs() < 0.1);
    }

    #[test]
    fn empty_is_nan() {
        let mut s = Samples::new();
        assert!(s.mean().is_nan());
        assert!(s.percentile(50.0).is_nan());
    }

    #[test]
    fn nan_sample_does_not_panic_percentile() {
        // Regression: partial_cmp().unwrap() panicked when a NaN had
        // been pushed (e.g. a rate computed from an empty window).
        let mut s = Samples::new();
        s.push(2.0);
        s.push(f64::NAN);
        s.push(1.0);
        s.push(3.0);
        assert_eq!(s.min(), 1.0);
        // positive NaN sorts last under total_cmp
        assert!((1.0..=3.0).contains(&s.percentile(50.0)));
        assert!(s.max().is_nan());
    }

    #[test]
    fn stddev_known() {
        let mut s = Samples::new();
        for x in [2.0, 4.0, 4.0, 4.0, 5.0, 5.0, 7.0, 9.0] {
            s.push(x);
        }
        assert!((s.stddev() - 2.138).abs() < 0.01);
    }

    #[test]
    fn histogram_quantiles() {
        let mut h = LogHistogram::new(1.0, 20);
        for i in 1..=1000 {
            h.record(i as f64);
        }
        let q50 = h.quantile_upper(0.5);
        assert!((500.0..=1024.0).contains(&q50), "q50 {}", q50);
        assert_eq!(h.total(), 1000);
    }

    #[test]
    fn summary_display() {
        let mut s = Samples::new();
        s.push(1.0);
        s.push(2.0);
        let sum = s.summary();
        assert_eq!(sum.n, 2);
        assert!(format!("{}", sum).contains("n=2"));
    }
}
