//! A small fixed-size worker pool over std::thread + mpsc (no tokio in
//! the offline vendor set). Used by the TCP server to run request
//! handlers off the accept loop, and by benches for load generation.

use std::sync::mpsc;
use std::sync::{Arc, Mutex};
use std::thread;

type Job = Box<dyn FnOnce() + Send + 'static>;

pub struct ThreadPool {
    tx: Option<mpsc::Sender<Job>>,
    workers: Vec<thread::JoinHandle<()>>,
}

impl ThreadPool {
    pub fn new(n: usize) -> Self {
        assert!(n > 0);
        let (tx, rx) = mpsc::channel::<Job>();
        let rx = Arc::new(Mutex::new(rx));
        let workers = (0..n)
            .map(|i| {
                let rx = Arc::clone(&rx);
                thread::Builder::new()
                    .name(format!("tardis-worker-{i}"))
                    .spawn(move || loop {
                        let job = { rx.lock().unwrap().recv() };
                        match job {
                            Ok(job) => job(),
                            Err(_) => break, // sender dropped: shut down
                        }
                    })
                    .expect("spawn worker")
            })
            .collect();
        ThreadPool { tx: Some(tx), workers }
    }

    /// Number of worker threads.
    pub fn size(&self) -> usize {
        self.workers.len()
    }

    pub fn execute<F: FnOnce() + Send + 'static>(&self, f: F) {
        self.tx
            .as_ref()
            .expect("pool shut down")
            .send(Box::new(f))
            .expect("workers alive");
    }

    /// Run `f` over all items, collecting results in order.
    pub fn map<T, R, F>(&self, items: Vec<T>, f: F) -> Vec<R>
    where
        T: Send + 'static,
        R: Send + 'static,
        F: Fn(T) -> R + Send + Sync + 'static,
    {
        let f = Arc::new(f);
        let (tx, rx) = mpsc::channel();
        let n = items.len();
        for (i, item) in items.into_iter().enumerate() {
            let tx = tx.clone();
            let f = Arc::clone(&f);
            self.execute(move || {
                let _ = tx.send((i, f(item)));
            });
        }
        let mut out: Vec<Option<R>> = (0..n).map(|_| None).collect();
        for _ in 0..n {
            let (i, r) = rx.recv().expect("worker result");
            out[i] = Some(r);
        }
        out.into_iter().map(|r| r.unwrap()).collect()
    }
}

impl Drop for ThreadPool {
    fn drop(&mut self) {
        drop(self.tx.take());
        for w in self.workers.drain(..) {
            let _ = w.join();
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::{AtomicUsize, Ordering};

    #[test]
    fn executes_all_jobs() {
        let pool = ThreadPool::new(4);
        let counter = Arc::new(AtomicUsize::new(0));
        for _ in 0..100 {
            let c = Arc::clone(&counter);
            pool.execute(move || {
                c.fetch_add(1, Ordering::SeqCst);
            });
        }
        drop(pool); // join
        assert_eq!(counter.load(Ordering::SeqCst), 100);
    }

    #[test]
    fn map_preserves_order() {
        let pool = ThreadPool::new(3);
        let out = pool.map((0..50).collect::<Vec<_>>(), |x| x * 2);
        assert_eq!(out, (0..50).map(|x| x * 2).collect::<Vec<_>>());
    }
}
