//! A small fixed-size worker pool over std::thread + mpsc.
//!
//! No tokio in the offline vendor set. Used by the TCP server to run
//! request handlers off the accept loop, by the [`crate::ffn::kernels`]
//! GEMM drivers for scoped tile fan-out ([`ThreadPool::broadcast`]), and
//! by benches for load generation.

use std::sync::mpsc;
use std::sync::{Arc, Mutex};
use std::thread;

type Job = Box<dyn FnOnce() + Send + 'static>;

pub struct ThreadPool {
    tx: Option<mpsc::Sender<Job>>,
    workers: Vec<thread::JoinHandle<()>>,
}

impl ThreadPool {
    pub fn new(n: usize) -> Self {
        assert!(n > 0);
        let (tx, rx) = mpsc::channel::<Job>();
        let rx = Arc::new(Mutex::new(rx));
        let workers = (0..n)
            .map(|i| {
                let rx = Arc::clone(&rx);
                thread::Builder::new()
                    .name(format!("tardis-worker-{i}"))
                    .spawn(move || loop {
                        let job = { rx.lock().unwrap().recv() };
                        match job {
                            Ok(job) => job(),
                            Err(_) => break, // sender dropped: shut down
                        }
                    })
                    .expect("spawn worker")
            })
            .collect();
        ThreadPool { tx: Some(tx), workers }
    }

    /// Number of worker threads.
    pub fn size(&self) -> usize {
        self.workers.len()
    }

    pub fn execute<F: FnOnce() + Send + 'static>(&self, f: F) {
        self.tx
            .as_ref()
            .expect("pool shut down")
            .send(Box::new(f))
            .expect("workers alive");
    }

    /// Run `f(0)`, `f(1)`, …, `f(jobs - 1)` across the pool, returning
    /// only after every job has finished.
    ///
    /// Unlike [`ThreadPool::map`], `f` may borrow from the caller's
    /// stack (no `'static` bound and no per-job input copies), which is
    /// what lets the GEMM drivers hand workers disjoint views of one
    /// output buffer and shared epilogue constants instead of cloning
    /// inputs per dispatch.
    pub fn broadcast<F>(&self, jobs: usize, f: F)
    where
        F: Fn(usize) + Sync,
    {
        if jobs == 0 {
            return;
        }
        let f_ref: &(dyn Fn(usize) + Sync) = &f;
        // SAFETY: the erased borrow cannot outlive `f`: every job sends
        // exactly one completion (even on panic, via catch_unwind), and
        // this function blocks on all `jobs` completions before
        // returning, so no job runs past the lifetime of `f` or of
        // anything it borrows.
        let f_static = unsafe {
            std::mem::transmute::<&(dyn Fn(usize) + Sync), &'static (dyn Fn(usize) + Sync)>(f_ref)
        };
        let (tx, rx) = mpsc::channel();
        for i in 0..jobs {
            let tx = tx.clone();
            self.execute(move || {
                let ok = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| f_static(i)))
                    .is_ok();
                let _ = tx.send(ok);
            });
        }
        drop(tx);
        let mut ok = true;
        for _ in 0..jobs {
            ok &= rx.recv().expect("pool worker died");
        }
        assert!(ok, "broadcast job panicked");
    }

    /// Run `f` over all items, collecting results in order.
    pub fn map<T, R, F>(&self, items: Vec<T>, f: F) -> Vec<R>
    where
        T: Send + 'static,
        R: Send + 'static,
        F: Fn(T) -> R + Send + Sync + 'static,
    {
        let f = Arc::new(f);
        let (tx, rx) = mpsc::channel();
        let n = items.len();
        for (i, item) in items.into_iter().enumerate() {
            let tx = tx.clone();
            let f = Arc::clone(&f);
            self.execute(move || {
                let _ = tx.send((i, f(item)));
            });
        }
        let mut out: Vec<Option<R>> = (0..n).map(|_| None).collect();
        for _ in 0..n {
            let (i, r) = rx.recv().expect("worker result");
            out[i] = Some(r);
        }
        out.into_iter().map(|r| r.unwrap()).collect()
    }
}

impl Drop for ThreadPool {
    fn drop(&mut self) {
        drop(self.tx.take());
        for w in self.workers.drain(..) {
            let _ = w.join();
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::{AtomicUsize, Ordering};

    #[test]
    fn executes_all_jobs() {
        let pool = ThreadPool::new(4);
        let counter = Arc::new(AtomicUsize::new(0));
        for _ in 0..100 {
            let c = Arc::clone(&counter);
            pool.execute(move || {
                c.fetch_add(1, Ordering::SeqCst);
            });
        }
        drop(pool); // join
        assert_eq!(counter.load(Ordering::SeqCst), 100);
    }

    #[test]
    fn map_preserves_order() {
        let pool = ThreadPool::new(3);
        let out = pool.map((0..50).collect::<Vec<_>>(), |x| x * 2);
        assert_eq!(out, (0..50).map(|x| x * 2).collect::<Vec<_>>());
    }

    #[test]
    fn broadcast_runs_all_jobs_with_borrowed_state() {
        let pool = ThreadPool::new(4);
        // borrowed (non-'static) output, one disjoint slot per job
        let mut out = vec![0usize; 37];
        let slots: Vec<Mutex<Option<&mut usize>>> =
            out.iter_mut().map(|v| Mutex::new(Some(v))).collect();
        pool.broadcast(slots.len(), |i| {
            let v = slots[i].lock().unwrap().take().unwrap();
            *v = i * i;
        });
        drop(slots); // release the borrows of `out`
        for (i, v) in out.iter().enumerate() {
            assert_eq!(*v, i * i);
        }
        // zero jobs is a no-op
        pool.broadcast(0, |_| unreachable!());
    }
}
