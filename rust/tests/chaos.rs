//! Chaos-harness integration tests for the fault-tolerant front door:
//! deterministic fault injection ([`FaultPlan`]) drives replica kills,
//! step failures, dropped connections, and journal recovery end to end,
//! asserting the robustness contract — zero lost admitted requests,
//! bitwise-identical streams for unaffected requests, explicit shedding
//! under overload — at the library and TCP layers.
//!
//! The CI chaos lane runs this suite with `TARDIS_ASSERT_ZERO_LOST=1`;
//! the zero-lost property is asserted unconditionally here (the env var
//! additionally gates the front-door bench in `benches/coordinator.rs`).

use std::collections::HashMap;
use std::path::PathBuf;
use std::thread;
use std::time::{Duration, Instant};

use tardis::coordinator::engine_loop::{EngineConfig, InferenceEngine};
use tardis::coordinator::health::{FaultPlan, HealthState};
use tardis::coordinator::journal::{Journal, JournalEntry};
use tardis::coordinator::model::MockModel;
use tardis::coordinator::request::SamplingParams;
use tardis::coordinator::router::{
    FrontDoor, FrontDoorConfig, FrontEnd, ReplicaFactory, SubmitOutcome,
};
use tardis::server::tcp::{client_roundtrip, client_roundtrip_with_retry, serve};
use tardis::util::json::Json;

fn mock_factory(spin_us: u64) -> ReplicaFactory<MockModel> {
    Box::new(move || {
        let mut m = MockModel::new(4, 128, 256, vec![4, 16]);
        m.spin_per_call = Duration::from_micros(spin_us);
        Ok(InferenceEngine::new(m, EngineConfig::default()))
    })
}

fn params(max_tokens: usize) -> SamplingParams {
    SamplingParams { max_tokens, ..Default::default() }
}

fn tmp(name: &str) -> PathBuf {
    let mut p = std::env::temp_dir();
    p.push(format!("tardis-chaos-{name}-{}", std::process::id()));
    p
}

fn ephemeral_addr() -> String {
    let listener = std::net::TcpListener::bind("127.0.0.1:0").unwrap();
    let addr = listener.local_addr().unwrap().to_string();
    drop(listener);
    addr
}

fn admit<M: tardis::coordinator::model::StepModel + Send + 'static>(
    front: &mut FrontDoor<M>,
    prompt: Vec<i32>,
    p: SamplingParams,
) -> u64 {
    match front.submit_front(None, prompt, p, false) {
        SubmitOutcome::Admitted { ticket, .. } => ticket,
        other => panic!("expected admission, got {other:?}"),
    }
}

/// The headline chaos scenario: two replicas, one killed mid-flight by
/// an injected panic. Every admitted request must still complete, with
/// token streams bitwise identical to a fault-free run, and the journal
/// must close out every admission.
#[test]
fn killed_replica_loses_no_admitted_requests() {
    let prompts: Vec<Vec<i32>> =
        (0..24).map(|i| vec![3 + i as i32, 7, 11 + (i % 5) as i32]).collect();
    let p = params(6);

    // Fault-free baseline: prompt -> generated tokens. The mock model
    // decodes deterministically from (token, pos), so streams must not
    // depend on which replica or batch composition served them.
    let mut baseline: HashMap<Vec<i32>, Vec<i32>> = HashMap::new();
    {
        let mut front = FrontDoor::new(
            vec![("mock".to_string(), mock_factory(0))],
            FrontDoorConfig::default(),
        )
        .unwrap();
        for prompt in &prompts {
            admit(&mut front, prompt.clone(), p);
        }
        for r in front.drain(Duration::from_secs(30)).unwrap() {
            let c = r.result.expect("baseline completion");
            baseline.insert(c.prompt.clone(), c.tokens.clone());
        }
    }
    assert_eq!(baseline.len(), prompts.len());

    // Chaos run: kill replica 1 at its 6th engine iteration, with the
    // admission journal on. The spin keeps work in flight at the kill.
    let journal = tmp("kill");
    let _ = std::fs::remove_file(&journal);
    let cfg = FrontDoorConfig {
        journal: Some(journal.clone()),
        fault_plan: FaultPlan::parse("kill:1@6").unwrap(),
        probe_base: Duration::from_millis(5),
        ..Default::default()
    };
    let mut front = FrontDoor::new(
        vec![
            ("mock".to_string(), mock_factory(200)),
            ("mock".to_string(), mock_factory(200)),
        ],
        cfg,
    )
    .unwrap();
    assert_eq!(front.replica_names(), vec!["mock-0", "mock-1"]);
    for prompt in &prompts {
        admit(&mut front, prompt.clone(), p);
    }
    let replies = front.drain(Duration::from_secs(30)).unwrap();

    // Zero lost admitted requests (the TARDIS_ASSERT_ZERO_LOST
    // contract), and every stream bitwise identical to the baseline.
    assert_eq!(replies.len(), prompts.len());
    for r in &replies {
        let c = r.result.as_ref().expect("completion despite the kill");
        assert_eq!(
            baseline[&c.prompt], c.tokens,
            "stream for prompt {:?} diverged after replay",
            c.prompt
        );
    }
    assert_eq!(front.stats.replica_failures, 1);
    assert!(front.stats.replays >= 1, "the dead replica held in-flight work");
    assert_eq!(front.stats.completed as usize, prompts.len());

    // The backoff probe restarts the dead replica.
    let t0 = Instant::now();
    while front.stats.replica_restarts == 0 && t0.elapsed() < Duration::from_secs(5) {
        front.pump(Duration::from_millis(5)).unwrap();
    }
    assert!(front.stats.replica_restarts >= 1);
    let (_, alive) = front.replica_health(1);
    assert!(alive);

    // Journal accounting: one admit and one done per request, no errors.
    let snap = front.front_snapshot();
    assert_eq!(snap.front.journal_appends, 2 * prompts.len() as u64);
    assert_eq!(snap.front.journal_errors, 0);
    assert!(snap.front.journal_bytes > 0);
    assert_eq!(snap.replicas.len(), 2);
    drop(front);
    let (pending, _, report) = Journal::recover(&journal).unwrap();
    assert!(pending.is_empty(), "every admission was closed out");
    assert_eq!(report.admits as usize, prompts.len());
    assert_eq!(report.dones as usize, prompts.len());
    let _ = std::fs::remove_file(&journal);
}

/// A step *error* (not a panic) on the only replica: the front door must
/// restart it from the factory and replay the orphaned work onto the new
/// incarnation, which then proves itself back to Healthy.
#[test]
fn failed_step_restarts_and_replays_on_same_replica() {
    let cfg = FrontDoorConfig {
        fault_plan: FaultPlan::parse("fail:0@4").unwrap(),
        probe_base: Duration::from_millis(5),
        ..Default::default()
    };
    let mut front =
        FrontDoor::new(vec![("mock".to_string(), mock_factory(100))], cfg).unwrap();
    for i in 0..8 {
        admit(&mut front, vec![40 + i, 2], params(6));
    }
    let replies = front.drain(Duration::from_secs(30)).unwrap();
    assert_eq!(replies.len(), 8);
    assert!(replies.iter().all(|r| r.result.is_ok()));
    assert_eq!(front.stats.replica_failures, 1);
    assert!(front.stats.replica_restarts >= 1);
    assert!(front.stats.replays >= 1);
    let (state, alive) = front.replica_health(0);
    assert!(alive);
    assert_eq!(state, HealthState::Healthy, "completions prove the restart out");
}

/// Crash-recovery round trip: admissions journaled by a previous process
/// incarnation (minus the completed one) replay at construction, finish,
/// and the ticket space continues past the journal's high-water mark.
#[test]
fn journal_recovery_replays_unfinished_admissions() {
    let path = tmp("recover");
    let _ = std::fs::remove_file(&path);
    {
        let mut j = Journal::open(&path).unwrap();
        let p = params(4);
        j.append_admit(&JournalEntry {
            ticket: 1,
            prompt: vec![5, 6],
            params: p,
            variant: None,
        })
        .unwrap();
        j.append_admit(&JournalEntry {
            ticket: 2,
            prompt: vec![7],
            params: p,
            variant: Some("mock".to_string()),
        })
        .unwrap();
        j.append_admit(&JournalEntry {
            ticket: 3,
            prompt: vec![9, 9],
            params: p,
            variant: None,
        })
        .unwrap();
        j.append_done(2, "length").unwrap();
    }
    let cfg = FrontDoorConfig { journal: Some(path.clone()), ..Default::default() };
    let mut front =
        FrontDoor::new(vec![("mock".to_string(), mock_factory(0))], cfg).unwrap();
    assert_eq!(front.stats.recovered, 2);
    assert_eq!(front.pending(), 2);
    let replies = front.drain(Duration::from_secs(10)).unwrap();
    assert_eq!(replies.len(), 2);
    assert!(replies.iter().all(|r| r.recovered && r.result.is_ok()));

    let ticket = admit(&mut front, vec![4, 2], params(2));
    assert!(ticket >= 4, "new tickets continue past the recovered ones");
    front.drain(Duration::from_secs(10)).unwrap();
    drop(front);

    let (pending, _, report) = Journal::recover(&path).unwrap();
    assert!(pending.is_empty());
    assert_eq!(report.admits, 4); // 3 pre-crash + 1 new (replays are not re-admitted)
    assert_eq!(report.dones, 4); // 1 pre-crash + 2 recovered + 1 new
    let _ = std::fs::remove_file(&path);
}

/// Past `queue_cap` in-flight per replica, submissions shed with an
/// explicit `retry_after_ms`; a retried submission is admitted and
/// counted once capacity frees up.
#[test]
fn overload_sheds_then_honors_retry() {
    let cfg = FrontDoorConfig { queue_cap: 2, ..Default::default() };
    let mut front =
        FrontDoor::new(vec![("mock".to_string(), mock_factory(2000))], cfg).unwrap();
    let p = params(2);
    let mut shed_after = None;
    for i in 0..3i32 {
        match front.submit_front(None, vec![10 + i], p, false) {
            SubmitOutcome::Admitted { .. } => assert!(i < 2),
            SubmitOutcome::Shed { retry_after_ms } => {
                assert_eq!(i, 2, "only the over-cap submission sheds");
                shed_after = Some(retry_after_ms);
            }
            other => panic!("unexpected outcome {other:?}"),
        }
    }
    let retry_after = shed_after.expect("third submission should shed");
    assert!((1..=500).contains(&retry_after));
    assert_eq!(front.stats.shed, 1);

    front.drain(Duration::from_secs(10)).unwrap();
    match front.submit_front(None, vec![13], p, true) {
        SubmitOutcome::Admitted { .. } => {}
        other => panic!("retry should be admitted, got {other:?}"),
    }
    assert_eq!(front.stats.retries_honored, 1);
    front.drain(Duration::from_secs(10)).unwrap();
    let snap = front.front_snapshot();
    assert_eq!(snap.front.shed, 1);
    assert_eq!(snap.front.completed, 3);
}

/// End-to-end overload over TCP: concurrent clients against one slow,
/// cap-1 replica. The retry helper backs off on `overloaded` responses
/// until every client is served.
#[test]
fn tcp_overload_retries_until_served() {
    let addr = ephemeral_addr();
    let cfg = FrontDoorConfig { queue_cap: 1, ..Default::default() };
    let front =
        FrontDoor::new(vec![("mock".to_string(), mock_factory(3000))], cfg).unwrap();
    let srv = {
        let addr = addr.clone();
        thread::spawn(move || serve(front, &addr, Some(4)).unwrap())
    };
    thread::sleep(Duration::from_millis(100));
    let clients: Vec<_> = (0..4)
        .map(|i| {
            let addr = addr.clone();
            thread::spawn(move || {
                let line = format!(
                    r#"{{"op":"generate","prompt":[{}],"max_tokens":2}}"#,
                    20 + i
                );
                client_roundtrip_with_retry(&addr, &line, 64, 42 + i as u64).unwrap()
            })
        })
        .collect();
    for c in clients {
        let out = c.join().unwrap();
        let j = Json::parse(&out.response).unwrap();
        assert_eq!(
            j.get("ok").and_then(Json::as_bool),
            Some(true),
            "client response after {} attempts: {}",
            out.attempts,
            out.response
        );
    }
    assert_eq!(srv.join().unwrap(), 4);
}

/// The dropconn fault marks exactly the targeted admission for reply
/// dropping; execution is unaffected (the request still completes and
/// journals), only its reply path vanishes.
#[test]
fn dropconn_fault_targets_exact_admission() {
    let cfg = FrontDoorConfig {
        fault_plan: FaultPlan::parse("dropconn@1").unwrap(),
        ..Default::default()
    };
    let mut front =
        FrontDoor::new(vec![("mock".to_string(), mock_factory(0))], cfg).unwrap();
    let p = params(2);
    let mut drops = Vec::new();
    for i in 0..3i32 {
        match front.submit_front(None, vec![30 + i], p, false) {
            SubmitOutcome::Admitted { drop_reply, .. } => drops.push(drop_reply),
            other => panic!("expected admission, got {other:?}"),
        }
    }
    assert_eq!(drops, vec![false, true, false]);
    let replies = front.drain(Duration::from_secs(10)).unwrap();
    assert_eq!(replies.len(), 3, "the front door still completes dropped requests");
    assert!(replies.iter().all(|r| r.result.is_ok()));
}

/// Same fault over TCP: the dropped client gets a prompt error (its
/// reply channel died), the others full completions — and the server
/// keeps counting all three toward `max_requests`, so a vanished client
/// cannot wedge a bounded serve.
#[test]
fn tcp_dropconn_does_not_wedge_bounded_serve() {
    let addr = ephemeral_addr();
    let cfg = FrontDoorConfig {
        fault_plan: FaultPlan::parse("dropconn@1").unwrap(),
        ..Default::default()
    };
    let front =
        FrontDoor::new(vec![("mock".to_string(), mock_factory(0))], cfg).unwrap();
    let srv = {
        let addr = addr.clone();
        thread::spawn(move || serve(front, &addr, Some(3)).unwrap())
    };
    thread::sleep(Duration::from_millis(100));
    let mut oks = 0;
    for i in 0..3 {
        let line =
            format!(r#"{{"op":"generate","prompt":[{}],"max_tokens":2}}"#, 50 + i);
        let resp = client_roundtrip(&addr, &line).unwrap();
        let j = Json::parse(&resp).unwrap();
        if j.get("ok").and_then(Json::as_bool) == Some(true) {
            oks += 1;
        }
    }
    assert_eq!(oks, 2, "exactly the dropped admission loses its reply");
    assert_eq!(srv.join().unwrap(), 3);
}
