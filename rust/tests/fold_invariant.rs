//! The fold invariant, from kernel level up through the scheduler:
//!
//! 1. For rows whose pre-activations all lie inside the approximated
//!    linear range, the folded FFN reproduces the partially-linearized
//!    dense FFN up to [`FOLD_TOL`] (property test over random
//!    shapes/weights, rows held under the provable radius).
//! 2. On mixed batches the predictor's fallback engages: outlier rows
//!    are routed down the dense path and match it *bitwise*, while
//!    in-range rows stay within [`FOLD_TOL`].
//! 3. The invariant survives the serving stack: for every scheduler
//!    policy, the exact prefill/decode call sequence the engine emits is
//!    replayed on a tardis NativeModel and its unfolded reference, and
//!    all logits must agree within [`LOGIT_TOL`].

use std::sync::Arc;

use anyhow::Result;

use tardis::config::{FfnMode, NativeModelConfig, TardisFfnConfig};
use tardis::coordinator::engine_loop::{EngineConfig, InferenceEngine};
use tardis::coordinator::model::{MockModel, NativeModel, StepModel};
use tardis::coordinator::request::SamplingParams;
use tardis::coordinator::scheduler::{PolicyKind, StepOutcome, StepPlan};
use tardis::ffn::kernels::{norm, Scratch};
use tardis::ffn::{DenseFfn, FoldedFfn};
use tardis::prop_assert;
use tardis::testing::property;
use tardis::util::rng::Rng;

/// Documented tolerance for in-range (folded) rows vs the dense
/// reference. The fold changes the summation order — `C` is accumulated
/// in f64 and the blocked kernels tile the reduction — so in-range rows
/// are *not* bitwise-equal; they agree to roundoff. `1e-3` relative
/// (≈ a few thousand f32 ULP at unit scale) bounds the reassociation
/// error with wide margin across the random shapes the property tests
/// draw. Outlier-fallback rows take the identical dense code path and
/// therefore stay **bitwise-exact** — asserted with `==`, no tolerance.
const FOLD_TOL: f32 = 1e-3;

/// End-to-end logit tolerance for the scheduler-level replay: the fold
/// error of [`FOLD_TOL`] per FFN compounds across layers and the final
/// unembedding, so logits get a wider (still tight) bound.
const LOGIT_TOL: f32 = 2e-2;

fn random_dense(rng: &mut Rng, d: usize, h: usize) -> DenseFfn {
    let scale = 0.4 / (d as f64).sqrt();
    let w_up: Vec<f32> =
        (0..d * h).map(|_| (rng.normal() * scale) as f32).collect();
    let b_up: Vec<f32> =
        (0..h).map(|_| (rng.normal() * 0.05) as f32).collect();
    let w_down: Vec<f32> =
        (0..h * d).map(|_| (rng.normal() * scale) as f32).collect();
    let b_down: Vec<f32> =
        (0..d).map(|_| (rng.normal() * 0.05) as f32).collect();
    DenseFfn::new(
        Arc::new(w_up),
        Arc::new(b_up),
        Arc::new(w_down),
        Arc::new(b_down),
        d,
        h,
    )
}

fn tardis_cfg(ratio: f64) -> TardisFfnConfig {
    TardisFfnConfig {
        fold_ratio: ratio,
        linear_lo: -6.0,
        linear_hi: 6.0,
        predictor_threshold: 1.0,
        ..TardisFfnConfig::default()
    }
}

/// Random row directions rescaled to a fixed norm.
fn rows_at_norm(rng: &mut Rng, rows: usize, d: usize, target: f32) -> Vec<f32> {
    let mut x = vec![0f32; rows * d];
    for row in x.chunks_mut(d) {
        for v in row.iter_mut() {
            *v = rng.normal() as f32;
        }
        let n = norm(row).max(1e-6);
        for v in row.iter_mut() {
            *v *= target / n;
        }
    }
    x
}

fn close(a: f32, b: f32, tol: f32) -> bool {
    (a - b).abs() <= tol * b.abs().max(1.0)
}

#[test]
fn folded_equals_dense_inside_linear_range() {
    property("fold invariant in-range", 40, |rng| {
        let d = 4 + rng.usize_below(12);
        let h = d + 1 + rng.usize_below(3 * d);
        let ratio = 0.4 + rng.f64() * 0.6;
        let dense = random_dense(rng, d, h);
        let mut folded = FoldedFfn::new(dense, &tardis_cfg(ratio));
        let r = folded.predictor.safe_radius();
        prop_assert!(r > 0.0, "degenerate safe radius {r}");
        let rows = 1 + rng.usize_below(6);
        let x = rows_at_norm(rng, rows, d, 0.9 * r);
        let mut scratch = Scratch::new();
        let got = folded.forward(None, &mut scratch, &x, rows);
        let want = folded.reference.forward(None, &mut scratch, &x, rows);
        for (i, (g, w)) in got.iter().zip(&want).enumerate() {
            prop_assert!(
                close(*g, *w, FOLD_TOL),
                "d={d} h={h} ratio={ratio:.2} elem {i}: folded {g} vs dense {w}"
            );
        }
        prop_assert!(
            folded.telemetry.fallback_rows == 0,
            "provably safe rows must not fall back"
        );
        Ok(())
    });
}

#[test]
fn fallback_bounds_error_on_mixed_batches() {
    property("fold fallback on outliers", 40, |rng| {
        let d = 4 + rng.usize_below(12);
        let h = d + 1 + rng.usize_below(3 * d);
        let dense = random_dense(rng, d, h);
        let mut folded = FoldedFfn::new(dense, &tardis_cfg(0.7));
        let r = folded.predictor.safe_radius();
        prop_assert!(r > 0.0, "degenerate safe radius {r}");
        // rows: a safe one, an outlier along folded column 0, a safe one.
        let h_total = folded.reference.d_ff;
        let mut x = rows_at_norm(rng, 3, d, 0.8 * r);
        for (l, v) in x[d..2 * d].iter_mut().enumerate() {
            *v = folded.reference.w_up[l * h_total];
        }
        let n1 = norm(&x[d..2 * d]).max(1e-9);
        let blow = 60.0 * r / n1;
        for v in x[d..2 * d].iter_mut() {
            *v *= blow;
        }
        let mut scratch = Scratch::new();
        let got = folded.forward(None, &mut scratch, &x, 3);
        let want = folded.reference.forward(None, &mut scratch, &x, 3);
        // outlier row falls back: bitwise equal to the dense path
        for (i, (g, w)) in got[d..2 * d].iter().zip(&want[d..2 * d]).enumerate()
        {
            prop_assert!(g == w, "fallback row elem {i}: {g} != {w}");
        }
        // in-range rows stay within fold roundoff
        for (i, (g, w)) in got[..d].iter().zip(&want[..d]).enumerate() {
            prop_assert!(close(*g, *w, FOLD_TOL), "row0 elem {i}: {g} vs {w}");
        }
        for (i, (g, w)) in got[2 * d..].iter().zip(&want[2 * d..]).enumerate() {
            prop_assert!(close(*g, *w, FOLD_TOL), "row2 elem {i}: {g} vs {w}");
        }
        prop_assert!(folded.telemetry.fallback_rows == 1,
                     "exactly the outlier row falls back");
        prop_assert!(folded.telemetry.folded_rows == 2);
        prop_assert!(folded.predictor.stats.observed_out_of_range == 1);
        Ok(())
    });
}

// ---------------------------------------------------------------------------
// Scheduler-level replay: the invariant across every policy.
// ---------------------------------------------------------------------------

#[derive(Default, Clone)]
struct CallLog {
    prefills: Vec<(usize, Vec<i32>, usize, usize, usize)>,
    decodes: Vec<(Vec<i32>, Vec<i32>)>,
}

/// Wraps the mock model, recording the exact call sequence the engine
/// issues under a given policy (schedules depend only on lengths, never
/// on token values, so the log replays verbatim on any backend).
struct RecordingModel {
    inner: MockModel,
    log: CallLog,
}

impl StepModel for RecordingModel {
    fn batch(&self) -> usize {
        self.inner.batch()
    }

    fn max_seq(&self) -> usize {
        self.inner.max_seq()
    }

    fn vocab(&self) -> usize {
        self.inner.vocab()
    }

    fn prefill_buckets(&self) -> &[usize] {
        self.inner.prefill_buckets()
    }

    fn plan_begin(&mut self, plan: &StepPlan) {
        self.inner.plan_begin(plan);
    }

    fn plan_end(&mut self, outcome: &StepOutcome) {
        self.inner.plan_end(outcome);
    }

    fn prefill(&mut self, bucket: usize, tokens: &[i32], real_len: usize,
               slot: usize, pos0: usize) -> Result<Vec<f32>> {
        self.log
            .prefills
            .push((bucket, tokens.to_vec(), real_len, slot, pos0));
        self.inner.prefill(bucket, tokens, real_len, slot, pos0)
    }

    fn decode(&mut self, tokens: &[i32], pos: &[i32]) -> Result<Vec<f32>> {
        self.log.decodes.push((tokens.to_vec(), pos.to_vec()));
        self.inner.decode(tokens, pos)
    }
}

fn native_cfg() -> NativeModelConfig {
    NativeModelConfig {
        vocab: 32,
        d_model: 32,
        n_layers: 2,
        n_heads: 2,
        d_ff: 64,
        max_seq: 64,
        batch: 4,
        prefill_buckets: vec![4, 8],
        seed: 0xF01D,
        threads: 0,
        kv_block_size: 16,
        kv_blocks: 0,
    }
}

/// Replay a recorded call sequence, returning all logits in call order.
fn replay(model: &mut NativeModel, log: &CallLog) -> Vec<f32> {
    let mut out = Vec::new();
    for (bucket, tokens, real_len, slot, pos0) in &log.prefills {
        out.extend(
            model
                .prefill(*bucket, tokens, *real_len, *slot, *pos0)
                .expect("prefill"),
        );
    }
    for (tokens, pos) in &log.decodes {
        out.extend(model.decode(tokens, pos).expect("decode"));
    }
    out
}

#[test]
fn fold_invariant_holds_across_all_scheduler_policies() {
    fold_invariant_replay(tardis::config::PredictorKind::Norm);
}

#[test]
fn fold_invariant_holds_with_quantized_predictor() {
    // Same replay, routed by the k-bit per-neuron predictor: flagged
    // neurons are fixed exactly, over-capacity rows fall back densely,
    // so the invariant is preserved under per-neuron routing too.
    fold_invariant_replay(tardis::config::PredictorKind::Quantized);
}

fn fold_invariant_replay(predictor: tardis::config::PredictorKind) {
    // Pre-activations post-LN are ~N(0,1); ±8 keeps every row in range
    // so tardis vs reference differ only by the fold's reassociation.
    let t = TardisFfnConfig {
        fold_ratio: 0.8,
        linear_lo: -8.0,
        linear_hi: 8.0,
        predictor_threshold: 1.05,
        predictor,
        ..TardisFfnConfig::default()
    };
    for policy in PolicyKind::all() {
        let mut cfg = EngineConfig::default();
        cfg.scheduler.policy = policy;
        let mut engine = InferenceEngine::new(
            RecordingModel {
                inner: MockModel::new(4, 64, 32, vec![4, 8]),
                log: CallLog::default(),
            },
            cfg,
        );
        for i in 0..6i32 {
            let len = 1 + (5 * i as usize + 1) % 11;
            let prompt: Vec<i32> =
                (0..len as i32).map(|j| (i * 7 + j) % 32).collect();
            engine
                .submit(
                    prompt,
                    SamplingParams {
                        max_tokens: 3 + (i as usize % 4),
                        priority: i % 3,
                        ..Default::default()
                    },
                )
                .unwrap();
        }
        engine.run_to_completion().unwrap();
        let log = engine.model.log.clone();
        assert!(!log.prefills.is_empty() && !log.decodes.is_empty());

        let mut tardis =
            NativeModel::new(native_cfg(), &FfnMode::Tardis(t));
        let mut reference =
            NativeModel::new(native_cfg(), &FfnMode::TardisReference(t));
        let got = replay(&mut tardis, &log);
        let want = replay(&mut reference, &log);
        assert_eq!(got.len(), want.len());
        for (i, (g, w)) in got.iter().zip(&want).enumerate() {
            assert!(
                close(*g, *w, LOGIT_TOL),
                "policy {}: logit {i} diverged: tardis {g} vs reference {w}",
                policy.name()
            );
        }
        let tele = tardis.ffn_telemetry().expect("telemetry");
        assert!(tele.total_rows() > 0);
        assert!(
            tele.folded_rows > 0,
            "policy {}: the fold never engaged (fallback {}/{} rows)",
            policy.name(),
            tele.fallback_rows,
            tele.total_rows()
        );
    }
}
