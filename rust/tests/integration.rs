//! Integration tests over the real PJRT runtime + AOT artifacts.
//!
//! These need `make artifacts` to have run; they skip (not fail) when the
//! manifest is missing so `cargo test` works in a fresh checkout.
//! One #[test] drives everything sequentially — the PJRT CPU client is a
//! process-wide singleton and compilation dominates, so sharing one
//! engine keeps the suite fast.

use std::path::PathBuf;

use tardis::config::Manifest;
use tardis::coordinator::engine_loop::{EngineConfig, InferenceEngine};
use tardis::coordinator::model::{PjrtModel, StepModel};
use tardis::coordinator::request::{FinishReason, SamplingParams};
use tardis::runtime::Engine;

fn manifest_path() -> PathBuf {
    std::env::var("TARDIS_ARTIFACTS")
        .map(PathBuf::from)
        .unwrap_or_else(|_| {
            PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts")
        })
        .join("manifest.json")
}

#[test]
fn pjrt_end_to_end() {
    let path = manifest_path();
    if !path.exists() {
        eprintln!("SKIP: no artifacts at {} (run `make artifacts`)",
                  path.display());
        return;
    }
    let manifest = Manifest::load(&path).expect("manifest loads");
    assert!(manifest.variants.len() >= 2, "expected dense + tardis variants");
    let engine = Engine::cpu().expect("cpu client");

    // ---- dense variant: deterministic generation ----
    let v = engine
        .load_variant(&manifest, "dense", Some(&["decode", "prefill16"]))
        .expect("load dense");
    let model = PjrtModel::new(&engine, v, manifest.batch,
                               manifest.model.max_seq, manifest.model.vocab,
                               vec![16])
        .expect("model");
    let mut ie = InferenceEngine::new(model, EngineConfig::default());
    let prompt: Vec<i32> = "the falcon ".bytes().map(|b| b as i32).collect();
    let params = SamplingParams { max_tokens: 12, ..Default::default() };
    let c1 = ie.generate_sequential(prompt.clone(), params).expect("gen 1");
    assert_eq!(c1.tokens.len(), 12);
    assert_eq!(c1.reason, FinishReason::Length);
    // byte-level model trained on English-ish text: tokens are bytes
    assert!(c1.tokens.iter().all(|&t| (0..256).contains(&t)));

    // Greedy decoding must be reproducible.
    ie.model.reset_kv().expect("reset");
    let c2 = ie.generate_sequential(prompt.clone(), params).expect("gen 2");
    assert_eq!(c1.tokens, c2.tokens, "greedy generation must be deterministic");

    // ---- continuous batching: concurrent requests, same output ----
    ie.model.reset_kv().expect("reset");
    let mut ids = Vec::new();
    for i in 0..3 {
        let mut p = prompt.clone();
        p[0] += i as i32; // distinct prompts
        ids.push(ie.submit(p, params).expect("submit"));
    }
    let done = ie.run_to_completion().expect("batch run");
    assert_eq!(done.len(), 3);
    assert!(ie.stats.mean_occupancy() > 1.0,
            "occupancy {}", ie.stats.mean_occupancy());
    // the unmodified prompt's request must reproduce the sequential output
    let same = done.iter().find(|c| c.prompt == prompt).expect("same prompt");
    assert_eq!(same.tokens, c1.tokens,
               "batched decode must match sequential decode");

    // ---- tardis variant: produces sane text and runs the L1 kernels ----
    let vt = engine
        .load_variant(&manifest, "tardis80", Some(&["decode", "prefill16"]))
        .expect("load tardis80");
    assert!(vt.spec.compression_ratio > 0.75);
    let mt = PjrtModel::new(&engine, vt, manifest.batch,
                            manifest.model.max_seq, manifest.model.vocab,
                            vec![16])
        .expect("tardis model");
    let mut iet = InferenceEngine::new(mt, EngineConfig::default());
    let ct = iet.generate_sequential(prompt.clone(), params).expect("tardis gen");
    assert_eq!(ct.tokens.len(), 12);
    // folded model should still produce mostly printable ascii text
    let printable = ct.tokens.iter()
        .filter(|&&t| (32..127).contains(&t)).count();
    assert!(printable >= 9, "tardis output not text-like: {:?}", ct.tokens);

    // ---- FFN micro-executables exist and run (Fig 13/14 harness) ----
    let vm = engine
        .load_variant(&manifest, "tardis80",
                      Some(&["ffn_dense", "ffn_folded", "ffn_predictor"]))
        .expect("micro execs");
    let d = manifest.model.d_model;
    let x = engine.upload_f32(&vec![0.1f32; manifest.batch * d],
                              &[manifest.batch, d]).expect("x");
    let y = vm.exec("ffn_folded").expect("folded").run(&[&x]).expect("run");
    assert_eq!(y.len(), 1);
    let score = vm.exec("ffn_predictor").expect("pred").run(&[&x]).expect("run");
    assert_eq!(score.len(), 1);
}
