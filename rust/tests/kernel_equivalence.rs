//! Property tests for the blocked, pre-packed GEMM kernels:
//!
//! 1. Every packed kernel (all epilogues) matches the naive pre-PR
//!    scalar reference across random odd shapes — rows/k/m deliberately
//!    not multiples of the tile sizes, including the rows=1 decode case.
//! 2. Thread-count invariance: the parallel drivers are bitwise equal
//!    to the serial kernel for any worker count (the tile schedule is
//!    deterministic and each output element belongs to exactly one job).
//! 3. The row-sparse variant computes exactly the active subset (bitwise
//!    equal to the dense kernel row-for-row), leaves inactive rows
//!    untouched, and handles the empty/full split edge cases.
//! 4. The fused k-bit dequant GEMM ([`matmul_q_with`]) is bitwise equal
//!    to dequantize-then-f32-matmul on the portable path, across code
//!    widths {2,3,4,8}, odd group counts, decode-batch row counts 1..8
//!    and tile-tail shapes — the in-register decode is exactly the
//!    widened computation, minus the memory traffic.
//! 5. ISA paths: every entry of [`KernelDispatch::available()`] keeps
//!    thread-count invariance bitwise *within* that path; SIMD results
//!    may differ from portable only by FMA contraction, bounded by the
//!    same 1e-3 relative tolerance the fold-invariant suite uses.

use tardis::ffn::kernels::{
    gelu, matmul, matmul_naive, matmul_q_sparse_rows_with, matmul_q_with, matmul_sparse_rows,
    matmul_with, Epilogue, KernelDispatch, PackedMatrix, MR, NR,
};
use tardis::ffn::QuantizedProxy;
use tardis::prop_assert;
use tardis::testing::property;
use tardis::util::rng::Rng;
use tardis::util::threadpool::ThreadPool;

fn close(a: f32, b: f32, tol: f32) -> bool {
    (a - b).abs() <= tol * b.abs().max(1.0)
}

/// Random shape with every dimension coprime-ish to the tile sizes.
fn odd_shape(rng: &mut Rng) -> (usize, usize, usize) {
    let rows = 1 + rng.usize_below(2 * MR + 3);
    let k = 1 + rng.usize_below(50);
    let m = 1 + rng.usize_below(2 * NR + 7);
    (rows, k, m)
}

fn random_problem(rng: &mut Rng, rows: usize, k: usize, m: usize) -> (Vec<f32>, Vec<f32>, Vec<f32>) {
    let x: Vec<f32> = (0..rows * k).map(|_| rng.normal() as f32).collect();
    let w: Vec<f32> = (0..k * m).map(|_| rng.normal() as f32).collect();
    let b: Vec<f32> = (0..m).map(|_| rng.normal() as f32).collect();
    (x, w, b)
}

#[test]
fn packed_matches_naive_reference_on_odd_shapes() {
    property("packed vs naive", 60, |rng| {
        let (rows, k, m) = odd_shape(rng);
        let (x, wr, b) = random_problem(rng, rows, k, m);
        let w = PackedMatrix::pack(&wr, k, m);

        // Bias epilogue vs the naive kernel's bias-preinit path.
        let want = matmul_naive(&x, rows, k, &wr, m, Some(&b));
        let mut got = vec![0f32; rows * m];
        matmul(None, &x, rows, &w, Epilogue::Bias(&b), &mut got);
        for (i, (g, wv)) in got.iter().zip(&want).enumerate() {
            prop_assert!(
                close(*g, *wv, 1e-4),
                "bias rows={rows} k={k} m={m} elem {i}: {g} vs {wv}"
            );
        }

        // Store epilogue vs naive without bias.
        let want = matmul_naive(&x, rows, k, &wr, m, None);
        matmul(None, &x, rows, &w, Epilogue::Store, &mut got);
        for (i, (g, wv)) in got.iter().zip(&want).enumerate() {
            prop_assert!(
                close(*g, *wv, 1e-4),
                "store rows={rows} k={k} m={m} elem {i}: {g} vs {wv}"
            );
        }

        // Fused BiasGelu == gelu(Bias), bitwise.
        let mut biased = vec![0f32; rows * m];
        matmul(None, &x, rows, &w, Epilogue::Bias(&b), &mut biased);
        let mut fused = vec![0f32; rows * m];
        matmul(None, &x, rows, &w, Epilogue::BiasGelu(&b), &mut fused);
        for (i, (f, bv)) in fused.iter().zip(&biased).enumerate() {
            prop_assert!(*f == gelu(*bv), "gelu fusion elem {i}");
        }

        // Add into a bias-preloaded buffer == Bias, bitwise.
        let mut added: Vec<f32> = Vec::with_capacity(rows * m);
        for _ in 0..rows {
            added.extend_from_slice(&b);
        }
        matmul(None, &x, rows, &w, Epilogue::Add, &mut added);
        prop_assert!(added == biased, "accumulate epilogue diverged");
        Ok(())
    });
}

#[test]
fn single_row_decode_matches_naive() {
    property("rows=1 decode case", 30, |rng| {
        let k = 1 + rng.usize_below(70);
        let m = 1 + rng.usize_below(3 * NR);
        let (x, wr, b) = random_problem(rng, 1, k, m);
        let w = PackedMatrix::pack(&wr, k, m);
        let want = matmul_naive(&x, 1, k, &wr, m, Some(&b));
        let mut got = vec![0f32; m];
        matmul(None, &x, 1, &w, Epilogue::Bias(&b), &mut got);
        for (i, (g, wv)) in got.iter().zip(&want).enumerate() {
            prop_assert!(close(*g, *wv, 1e-4), "k={k} m={m} elem {i}: {g} vs {wv}");
        }
        Ok(())
    });
}

#[test]
fn results_are_invariant_across_thread_counts() {
    // Big enough to clear PARALLEL_THRESHOLD_OPS on both drivers.
    let mut rng = Rng::new(0x7EAD);
    let (rows, k, m) = (37, 128, 3 * NR + 5); // 478k ops: over the threshold
    let (x, wr, b) = random_problem(&mut rng, rows, k, m);
    let w = PackedMatrix::pack(&wr, k, m);
    let mut serial = vec![0f32; rows * m];
    matmul(None, &x, rows, &w, Epilogue::Bias(&b), &mut serial);
    for workers in [1, 2, 3, 5, 8] {
        let pool = ThreadPool::new(workers);
        let mut pooled = vec![0f32; rows * m];
        matmul(Some(&pool), &x, rows, &w, Epilogue::Bias(&b), &mut pooled);
        assert_eq!(serial, pooled, "row-parallel diverged at {workers} workers");
    }
    // single-row (column-parallel) driver
    let (k1, m1) = (512, 17 * NR + 9);
    let (x1, wr1, b1) = random_problem(&mut rng, 1, k1, m1);
    let w1 = PackedMatrix::pack(&wr1, k1, m1);
    let mut serial1 = vec![0f32; m1];
    matmul(None, &x1, 1, &w1, Epilogue::Bias(&b1), &mut serial1);
    for workers in [2, 4, 7] {
        let pool = ThreadPool::new(workers);
        let mut pooled1 = vec![0f32; m1];
        matmul(Some(&pool), &x1, 1, &w1, Epilogue::Bias(&b1), &mut pooled1);
        assert_eq!(serial1, pooled1, "col-parallel diverged at {workers} workers");
    }
    // row-sparse driver: pooled must match serial bitwise even when the
    // job chunking splits an active run that serial blocks MR-wide
    let active: Vec<bool> = (0..rows).map(|r| r % 5 != 3).collect();
    let mut s_serial = vec![0f32; rows * m];
    matmul_sparse_rows(None, &x, rows, &w, Epilogue::Bias(&b), &active, &mut s_serial);
    for workers in [2, 3, 6] {
        let pool = ThreadPool::new(workers);
        let mut s_pooled = vec![0f32; rows * m];
        matmul_sparse_rows(
            Some(&pool),
            &x,
            rows,
            &w,
            Epilogue::Bias(&b),
            &active,
            &mut s_pooled,
        );
        assert_eq!(s_serial, s_pooled, "sparse diverged at {workers} workers");
    }
}

#[test]
fn sparse_rows_match_dense_subset_bitwise() {
    property("sparse row splits", 40, |rng| {
        let (rows, k, m) = odd_shape(rng);
        let (x, wr, b) = random_problem(rng, rows, k, m);
        let w = PackedMatrix::pack(&wr, k, m);
        let mut dense = vec![0f32; rows * m];
        matmul(None, &x, rows, &w, Epilogue::Bias(&b), &mut dense);
        let active: Vec<bool> = (0..rows).map(|_| rng.f64() < 0.6).collect();
        let sentinel = -1234.5f32;
        let mut sparse = vec![sentinel; rows * m];
        matmul_sparse_rows(None, &x, rows, &w, Epilogue::Bias(&b), &active, &mut sparse);
        for r in 0..rows {
            let (got, want) = (&sparse[r * m..(r + 1) * m], &dense[r * m..(r + 1) * m]);
            if active[r] {
                prop_assert!(got == want, "active row {r} not bitwise-equal");
            } else {
                prop_assert!(
                    got.iter().all(|&v| v == sentinel),
                    "inactive row {r} was written"
                );
            }
        }
        // empty split: a fully-inactive mask writes nothing
        let mut untouched = vec![sentinel; rows * m];
        matmul_sparse_rows(
            None,
            &x,
            rows,
            &w,
            Epilogue::Bias(&b),
            &vec![false; rows],
            &mut untouched,
        );
        prop_assert!(untouched.iter().all(|&v| v == sentinel), "empty split wrote");
        // full split: bitwise equal to the dense kernel
        let mut full = vec![sentinel; rows * m];
        matmul_sparse_rows(None, &x, rows, &w, Epilogue::Bias(&b), &vec![true; rows], &mut full);
        prop_assert!(full == dense, "full split diverged from dense kernel");
        Ok(())
    });
}

/// The fused dequant GEMM's defining property: decoding codes in
/// registers is *exactly* the computation you would get by widening to
/// an f32 matrix first — same values, same rounding, element for
/// element — on the portable path. (SIMD relaxes this to the FMA
/// tolerance; see `simd_paths_match_portable_within_tolerance`.)
#[test]
fn fused_qgemm_matches_dequantized_matmul_bitwise() {
    property("fused k-bit GEMM vs dequantize+matmul", 60, |rng| {
        let bits = [2u8, 3, 4, 8][rng.usize_below(4)];
        // group=7 leaves a ragged final group whenever 7 ∤ k.
        let group = [7usize, 16, 32][rng.usize_below(3)];
        let rows = 1 + rng.usize_below(8); // every decode-batch size 1..8
        let k = 1 + rng.usize_below(70);
        let m = 1 + rng.usize_below(3 * NR + 9);
        let (x, wr, b) = random_problem(rng, rows, k, m);
        let proxy = QuantizedProxy::quantize(&wr, k, m, m, bits, group);
        let panels = proxy.panels();

        let widened = PackedMatrix::pack(&panels.dequantize(), k, m);
        let mut want = vec![0f32; rows * m];
        let disp = KernelDispatch::Portable;
        matmul_with(disp, None, &x, rows, &widened, Epilogue::Bias(&b), &mut want);
        let mut got = vec![0f32; rows * m];
        matmul_q_with(disp, None, &x, rows, panels, Epilogue::Bias(&b), &mut got);
        prop_assert!(
            got == want,
            "fused bits={bits} group={group} rows={rows} k={k} m={m} \
             diverged from the widened reference"
        );
        Ok(())
    });
}

#[test]
fn quant_sparse_rows_match_dense_subset_bitwise() {
    property("quant sparse row splits", 40, |rng| {
        let bits = [2u8, 4, 8][rng.usize_below(3)];
        let (rows, k, m) = odd_shape(rng);
        let (x, wr, b) = random_problem(rng, rows, k, m);
        let proxy = QuantizedProxy::quantize(&wr, k, m, m, bits, 16);
        let p = proxy.panels();
        let disp = KernelDispatch::Portable;
        let mut dense = vec![0f32; rows * m];
        matmul_q_with(disp, None, &x, rows, p, Epilogue::Bias(&b), &mut dense);

        let active: Vec<bool> = (0..rows).map(|_| rng.f64() < 0.6).collect();
        let sentinel = -1234.5f32;
        let mut sparse = vec![sentinel; rows * m];
        matmul_q_sparse_rows_with(
            disp,
            None,
            &x,
            rows,
            p,
            Epilogue::Bias(&b),
            &active,
            &mut sparse,
        );
        for r in 0..rows {
            let (got, want) = (&sparse[r * m..(r + 1) * m], &dense[r * m..(r + 1) * m]);
            if active[r] {
                prop_assert!(got == want, "active row {r} not bitwise-equal");
            } else {
                prop_assert!(
                    got.iter().all(|&v| v == sentinel),
                    "inactive row {r} was written"
                );
            }
        }
        // empty split: writes nothing
        let mut untouched = vec![sentinel; rows * m];
        let none = vec![false; rows];
        matmul_q_sparse_rows_with(
            disp,
            None,
            &x,
            rows,
            p,
            Epilogue::Bias(&b),
            &none,
            &mut untouched,
        );
        prop_assert!(untouched.iter().all(|&v| v == sentinel), "empty split wrote");
        // full split: bitwise equal to the dense fused kernel
        let mut full = vec![sentinel; rows * m];
        let all = vec![true; rows];
        matmul_q_sparse_rows_with(disp, None, &x, rows, p, Epilogue::Bias(&b), &all, &mut full);
        prop_assert!(full == dense, "full split diverged from dense fused kernel");
        Ok(())
    });
}

/// Bitwise thread-count invariance must hold separately on *every*
/// executable dispatch path (the tile schedule is deterministic and
/// each output element belongs to exactly one job, whichever family
/// computes the tile) — for the f32 driver on row-parallel, the
/// small-batch column-parallel schedule (rows 2..7), and the fused
/// quant driver.
#[test]
fn thread_invariance_holds_on_every_dispatch_path() {
    let mut rng = Rng::new(0xD15B);
    for disp in KernelDispatch::available() {
        // multi-row shape: row-parallel driver
        let (rows, k, m) = (37, 128, 3 * NR + 5);
        let (x, wr, b) = random_problem(&mut rng, rows, k, m);
        let w = PackedMatrix::pack(&wr, k, m);
        let mut serial = vec![0f32; rows * m];
        matmul_with(disp, None, &x, rows, &w, Epilogue::Bias(&b), &mut serial);
        for workers in [2, 3, 5] {
            let pool = ThreadPool::new(workers);
            let mut pooled = vec![0f32; rows * m];
            matmul_with(disp, Some(&pool), &x, rows, &w, Epilogue::Bias(&b), &mut pooled);
            assert_eq!(
                serial,
                pooled,
                "{} row-parallel diverged at {workers} workers",
                disp.name()
            );
        }
        // small decode batch: multi-row column-parallel driver
        let (rows2, k2, m2) = (3, 512, 17 * NR + 9);
        let (x2, wr2, b2) = random_problem(&mut rng, rows2, k2, m2);
        let w2 = PackedMatrix::pack(&wr2, k2, m2);
        let mut serial2 = vec![0f32; rows2 * m2];
        matmul_with(disp, None, &x2, rows2, &w2, Epilogue::Bias(&b2), &mut serial2);
        for workers in [2, 4, 7] {
            let pool = ThreadPool::new(workers);
            let mut pooled2 = vec![0f32; rows2 * m2];
            matmul_with(disp, Some(&pool), &x2, rows2, &w2, Epilogue::Bias(&b2), &mut pooled2);
            assert_eq!(
                serial2,
                pooled2,
                "{} col-parallel diverged at {workers} workers",
                disp.name()
            );
        }
        // fused quant driver over the multi-row shape
        let proxy = QuantizedProxy::quantize(&wr, k, m, m, 4, 32);
        let p = proxy.panels();
        let mut qserial = vec![0f32; rows * m];
        matmul_q_with(disp, None, &x, rows, p, Epilogue::Bias(&b), &mut qserial);
        for workers in [2, 3, 6] {
            let pool = ThreadPool::new(workers);
            let mut qpooled = vec![0f32; rows * m];
            matmul_q_with(disp, Some(&pool), &x, rows, p, Epilogue::Bias(&b), &mut qpooled);
            assert_eq!(
                qserial,
                qpooled,
                "{} fused quant diverged at {workers} workers",
                disp.name()
            );
        }
    }
}

/// SIMD paths agree with portable to the fold tolerance: the only
/// permitted divergence is FMA contraction inside the micro-kernel
/// (measured ~1e-6 relative on these shapes; budget is FOLD_TOL=1e-3,
/// the same bound `tests/fold_invariant.rs` grants the fold itself).
#[test]
fn simd_paths_match_portable_within_tolerance() {
    const SIMD_TOL: f32 = 1e-3;
    let mut rng = Rng::new(0x51AD);
    let shapes = [(1usize, 512usize, 17 * NR + 9), (5, 128, 3 * NR + 5), (37, 96, 2 * NR + 1)];
    for (rows, k, m) in shapes {
        let (x, wr, b) = random_problem(&mut rng, rows, k, m);
        let w = PackedMatrix::pack(&wr, k, m);
        let proxy = QuantizedProxy::quantize(&wr, k, m, m, 4, 32);
        let mut base = vec![0f32; rows * m];
        matmul_with(KernelDispatch::Portable, None, &x, rows, &w, Epilogue::Bias(&b), &mut base);
        let mut qbase = vec![0f32; rows * m];
        matmul_q_with(
            KernelDispatch::Portable,
            None,
            &x,
            rows,
            proxy.panels(),
            Epilogue::Bias(&b),
            &mut qbase,
        );
        for disp in KernelDispatch::available() {
            if disp == KernelDispatch::Portable {
                continue;
            }
            let mut got = vec![0f32; rows * m];
            matmul_with(disp, None, &x, rows, &w, Epilogue::Bias(&b), &mut got);
            for (i, (g, p)) in got.iter().zip(&base).enumerate() {
                assert!(
                    close(*g, *p, SIMD_TOL),
                    "{} f32 rows={rows} k={k} m={m} elem {i}: {g} vs {p}",
                    disp.name()
                );
            }
            let mut qgot = vec![0f32; rows * m];
            matmul_q_with(disp, None, &x, rows, proxy.panels(), Epilogue::Bias(&b), &mut qgot);
            for (i, (g, p)) in qgot.iter().zip(&qbase).enumerate() {
                assert!(
                    close(*g, *p, SIMD_TOL),
                    "{} fused rows={rows} k={k} m={m} elem {i}: {g} vs {p}",
                    disp.name()
                );
            }
        }
    }
}
