//! Golden-fixture round-trip of the native manifest convention:
//! `python -m compile.native_export` wrote
//! `tests/data/native_manifest/` (manifest + weight blob with per-neuron
//! calibrated ranges and the quantized `W1` proxy); this test proves the
//! rust side loads it **bitwise** and runs it end to end.
//!
//! Regenerate the fixture (and update the golden bit patterns below)
//! with:
//!
//! ```text
//! cd python && python -m compile.native_export \
//!     --out ../rust/tests/data/native_manifest
//! ```

use std::path::PathBuf;

use tardis::config::{FfnMode, Manifest, NativeModelConfig, PredictorKind};
use tardis::coordinator::model::{NativeModel, StepModel};
use tardis::runtime::weights::{NativeWeights, WeightFile};

fn fixture_path() -> PathBuf {
    PathBuf::from(env!("CARGO_MANIFEST_DIR"))
        .join("tests/data/native_manifest/manifest.json")
}

fn fixture_manifest() -> Manifest {
    Manifest::load(&fixture_path()).expect("golden fixture parses")
}

/// Shape the fixture was exported at (see `NativeExportConfig`).
fn fixture_cfg(m: &Manifest) -> NativeModelConfig {
    NativeModelConfig {
        vocab: m.model.vocab,
        d_model: m.model.d_model,
        n_layers: m.model.n_layers,
        n_heads: m.model.n_heads,
        d_ff: m.model.d_ff,
        max_seq: m.model.max_seq,
        batch: m.batch,
        prefill_buckets: m.prefill_buckets.clone(),
        seed: 0,
        threads: 0,
        kv_block_size: 16,
        kv_blocks: 0,
    }
}

#[test]
fn fixture_manifest_parses_with_predictor_fields() {
    let m = fixture_manifest();
    assert_eq!(m.model.d_model, 16);
    assert_eq!(m.model.d_ff, 32);
    assert_eq!(m.model.n_layers, 2);
    assert_eq!(m.model.vocab, 32);
    assert_eq!(m.variant_names(), vec!["dense", "tardis80"]);
    assert!(m.variant("dense").unwrap().tardis.is_none());
    let t = m.variant("tardis80").unwrap().tardis.expect("tardis cfg");
    assert!((t.fold_ratio - 0.8).abs() < 1e-12);
    assert_eq!(t.predictor, PredictorKind::Quantized);
    assert_eq!(t.predictor_bits, 4);
    assert_eq!(t.predictor_group, 8);
    assert_eq!(t.top_k, 4);
}

#[test]
fn calibration_arrays_roundtrip_bitwise() {
    let m = fixture_manifest();
    let cfg = fixture_cfg(&m);
    let spec = m.variant("tardis80").unwrap();
    let wf = WeightFile::load(&m.dir, spec).unwrap();
    let w = NativeWeights::from_weight_file(&wf, spec, &cfg).unwrap();
    let (d, h) = (cfg.d_model, cfg.d_ff);
    for (i, lw) in w.layers.iter().enumerate() {
        let calib = lw.calib.as_ref().expect("fixture ships calibration");
        let n = |s: &str| format!("layers.{i}.tardis.{s}");
        // Bitwise equality against the raw file bytes — the exact arrays
        // python wrote, through the full param-table plumbing.
        let raw = |s: &str| wf.f32_slice(spec.param(&n(s)).unwrap()).unwrap();
        assert_eq!(calib.lo, raw("lo"), "layer {i} lo");
        assert_eq!(calib.hi, raw("hi"), "layer {i} hi");
        assert_eq!(calib.lin_a, raw("lin_a"), "layer {i} lin_a");
        assert_eq!(calib.lin_b, raw("lin_b"), "layer {i} lin_b");
        assert_eq!(
            calib.pred_codes,
            wf.i8_slice(spec.param(&n("pred_codes")).unwrap()).unwrap(),
            "layer {i} codes"
        );
        assert_eq!(
            calib.pred_scales,
            wf.f32_slice(spec.param(&n("pred_scales")).unwrap()).unwrap(),
            "layer {i} scales"
        );
        assert_eq!(calib.lo.len(), h);
        assert_eq!(calib.pred_codes.len(), d * h);
        assert_eq!(calib.group, 8, "group implied by the scales shape");
        // per-neuron, not uniform — the point of the calibration
        let first = calib.lo[0];
        assert!(calib.lo.iter().any(|&v| v != first));
        for (&lo, &hi) in calib.lo.iter().zip(&calib.hi) {
            assert!(lo < hi, "layer {i}: empty range [{lo}, {hi})");
        }
    }
}

#[test]
fn golden_values_match_python_export() {
    // Spot values recorded from the generating python run — guards byte
    // order, offsets, and dtype decoding, and pins the fixture itself:
    // a regenerated fixture must update these alongside.
    let m = fixture_manifest();
    let cfg = fixture_cfg(&m);
    let spec = m.variant("tardis80").unwrap();
    let w = NativeWeights::load(&m.dir, spec, &cfg).unwrap();
    assert_eq!(w.embed[0].to_bits(), 0xbda6_e1ad);
    assert_eq!(w.embed[1].to_bits(), 0x3ca4_647a);
    assert_eq!(w.layers[0].w1[0].to_bits(), 0x3ce7_e70f);
    let c0 = w.layers[0].calib.as_ref().unwrap();
    assert_eq!(c0.lo[0].to_bits(), 0xc02d_66dd);
    assert_eq!(c0.hi[0].to_bits(), 0x400b_c2ea);
    assert_eq!(c0.lin_a[0].to_bits(), 0x3ee7_6fce);
    assert_eq!(c0.lin_b[0].to_bits(), 0x3e54_89d3);
    assert_eq!(&c0.pred_codes[..6], &[1, -2, 7, 6, -6, -7]);
    assert_eq!(c0.pred_scales[0].to_bits(), 0x3d62_eae7);
    let c1 = w.layers[1].calib.as_ref().unwrap();
    assert_eq!(c1.lo[5].to_bits(), 0xc00c_b85b);
    let h = cfg.d_ff;
    assert_eq!(&c1.pred_codes[3 * h..3 * h + 6], &[-1, 3, -3, -1, -3, 4]);
}

#[test]
fn calibrated_quantized_model_runs_end_to_end() {
    let m = fixture_manifest();
    let cfg = fixture_cfg(&m);
    let spec = m.variant("tardis80").unwrap();
    let t = spec.tardis.expect("tardis cfg");
    let mode = FfnMode::Tardis(t);
    let mut model = NativeModel::with_weights(
        cfg.clone(),
        NativeWeights::load(&m.dir, spec, &cfg).unwrap(),
        &mode,
    );
    let mut reference = NativeModel::with_weights(
        cfg.clone(),
        NativeWeights::load(&m.dir, spec, &cfg).unwrap(),
        &FfnMode::TardisReference(t),
    );
    assert_eq!(model.ffn_mode_name(), "tardis");
    assert!(model.fold_compression_ratio().unwrap() > 0.2);

    let lp_t = model.prefill(4, &[2, 5, 9, 0], 3, 0, 0).unwrap();
    let lp_r = reference.prefill(4, &[2, 5, 9, 0], 3, 0, 0).unwrap();
    let (mut num, mut den) = (0f64, 0f64);
    for (a, b) in lp_t.iter().zip(&lp_r) {
        assert!(a.is_finite());
        num += (a - b).abs() as f64;
        den += b.abs() as f64;
    }
    for s in 0..8 {
        let dt = model.decode(&[s, s + 1], &[s, s]).unwrap();
        let dr = reference.decode(&[s, s + 1], &[s, s]).unwrap();
        for (a, b) in dt.iter().zip(&dr) {
            assert!(a.is_finite());
            num += (a - b).abs() as f64;
            den += b.abs() as f64;
        }
    }
    // The calibrated ranges cover ~97% of activations; flagged neurons
    // are fixed exactly and over-capacity rows fall back, so the folded
    // model tracks its per-neuron reference closely in aggregate.
    assert!(num / den < 0.05, "mean relative logit drift {}", num / den);
    let tele = model.ffn_telemetry().expect("tardis telemetry");
    assert!(tele.total_rows() > 0);
    assert!(
        tele.folded_rows > 0,
        "the calibrated fold never engaged ({tele:?})"
    );
}

#[test]
fn dense_variant_shares_the_blob() {
    let m = fixture_manifest();
    let cfg = fixture_cfg(&m);
    let spec = m.variant("dense").unwrap();
    let mut model = NativeModel::with_weights(
        cfg.clone(),
        NativeWeights::load(&m.dir, spec, &cfg).unwrap(),
        &FfnMode::Dense,
    );
    assert_eq!(model.ffn_mode_name(), "dense");
    assert!(model.ffn_telemetry().is_none());
    let logits = model.decode(&[1, 2], &[0, 0]).unwrap();
    assert_eq!(logits.len(), 2 * cfg.vocab);
    assert!(logits.iter().all(|v| v.is_finite()));
}

#[test]
fn partial_calibration_is_rejected() {
    // A manifest shipping `tardis.lo` must ship the whole set: drop the
    // codes param and the load must fail loudly instead of silently
    // falling back to uniform ranges.
    let m = fixture_manifest();
    let cfg = fixture_cfg(&m);
    let mut spec = m.variant("tardis80").unwrap().clone();
    spec.params.retain(|p| !p.name.ends_with("tardis.pred_codes"));
    let wf = WeightFile::load(&m.dir, &spec).unwrap();
    let err = NativeWeights::from_weight_file(&wf, &spec, &cfg);
    assert!(err.is_err(), "partial calibration must not load");
}
