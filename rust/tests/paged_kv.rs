//! Std-only integration tests for the paged KV subsystem: block
//! accounting under engine traffic, and the preemption contract — a
//! request evicted to the host swap pool and restored into *different*
//! physical blocks must produce exactly the token stream of an
//! uninterrupted run, under every scheduler policy.

use tardis::config::{FfnMode, NativeModelConfig};
use tardis::coordinator::engine_loop::{EngineConfig, InferenceEngine};
use tardis::coordinator::model::{MockModel, NativeModel};
use tardis::coordinator::request::SamplingParams;
use tardis::coordinator::scheduler::PolicyKind;
use tardis::coordinator::StepModel;
use tardis::prop_assert;
use tardis::testing::property;
use tardis::util::rng::Rng;

#[derive(Clone)]
struct Spec {
    prompt: Vec<i32>,
    params: SamplingParams,
}

/// Mock engine over an undersized block pool: `blocks` blocks of
/// `block_size` tokens shared by 4 slots.
fn pressured_mock(blocks: usize, block_size: usize) -> InferenceEngine<MockModel> {
    let model = MockModel::new(4, 64, 16, vec![4, 8]).with_kv_layout(blocks, block_size);
    InferenceEngine::new(model, EngineConfig::default())
}

fn run_batched(
    specs: &[Spec],
    mut engine: InferenceEngine<MockModel>,
) -> (Vec<Vec<i32>>, u64) {
    let ids: Vec<u64> = specs
        .iter()
        .map(|s| engine.submit(s.prompt.clone(), s.params).unwrap())
        .collect();
    let done = engine.run_to_completion().unwrap();
    let streams = ids
        .iter()
        .map(|id| {
            done.iter()
                .find(|c| c.id == *id)
                .expect("request completed")
                .tokens
                .clone()
        })
        .collect();
    (streams, engine.stats.preemptions)
}

/// Sequential reference over the SAME pressured layout (so context
/// clamping matches), one request at a time — no batch-mates, and with a
/// single request in flight the pool never forces a preemption.
fn sequential_reference(specs: &[Spec], blocks: usize, block_size: usize) -> Vec<Vec<i32>> {
    let mut engine = pressured_mock(blocks, block_size);
    let mut out = Vec::new();
    for s in specs {
        let c = engine
            .generate_sequential(s.prompt.clone(), s.params)
            .unwrap();
        out.push(c.tokens);
    }
    assert_eq!(
        engine.stats.preemptions, 0,
        "a lone request must never be preempted"
    );
    out
}

#[test]
fn preempted_requests_replay_exactly_across_all_policies() {
    // 4 slots, 6 blocks x 4 tokens: four requests growing to 15 tokens
    // each demand 16 blocks of the 6 that exist, so the engine must
    // preempt and restore continuously — without changing any stream.
    let specs: Vec<Spec> = (0..4)
        .map(|i| Spec {
            prompt: vec![1 + i; 5],
            params: SamplingParams { max_tokens: 10, ..Default::default() },
        })
        .collect();
    let reference = sequential_reference(&specs, 6, 4);
    for kind in PolicyKind::all() {
        let mut cfg = EngineConfig::default();
        cfg.scheduler.policy = kind;
        let model = MockModel::new(4, 64, 16, vec![4, 8]).with_kv_layout(6, 4);
        let (streams, preemptions) =
            run_batched(&specs, InferenceEngine::new(model, cfg));
        assert!(preemptions > 0, "policy {kind:?}: pool pressure must preempt");
        assert_eq!(
            streams, reference,
            "policy {kind:?} changed outputs under preemption"
        );
    }
}

#[test]
fn prop_preemption_is_invisible_to_token_streams() {
    // Random traffic (mixed lengths, temperatures, priorities) over a
    // random undersized pool: every policy, with however many
    // preempt/swap/restore cycles, reproduces the sequential reference.
    property("preemption replay invariance", 20, |rng: &mut Rng| {
        let blocks = 5 + rng.usize_below(4); // 5..8 blocks of 4 => 20..32 tokens
        let block_size = 4;
        let eff = blocks * block_size; // engine clamps context to the pool
        let n = 2 + rng.usize_below(4);
        let specs: Vec<Spec> = (0..n)
            .map(|_| {
                let len = 1 + rng.usize_below(8);
                let prompt: Vec<i32> =
                    (0..len).map(|_| rng.below(16) as i32).collect();
                let params = SamplingParams {
                    temperature: if rng.bool(0.5) { 0.0 } else { 0.8 },
                    top_k: if rng.bool(0.5) { 0 } else { 1 + rng.usize_below(8) },
                    max_tokens: 1 + rng.usize_below(eff - 9),
                    stop_token: None,
                    seed: rng.next_u64(),
                    priority: rng.below(5) as i32,
                    ..Default::default()
                };
                Spec { prompt, params }
            })
            .collect();
        let reference = sequential_reference(&specs, blocks, block_size);
        for kind in PolicyKind::all() {
            // Both planners: mixed co-scheduling and the segregated
            // baseline preempt/resume identically under pressure.
            for mixed in [true, false] {
                let mut cfg = EngineConfig::default();
                cfg.scheduler.policy = kind;
                cfg.scheduler.mixed = mixed;
                let model = MockModel::new(4, 64, 16, vec![4, 8])
                    .with_kv_layout(blocks, block_size);
                let (streams, preemptions) =
                    run_batched(&specs, InferenceEngine::new(model, cfg));
                prop_assert!(
                    streams == reference,
                    "policy {kind:?} (mixed={mixed}) diverged under block \
                     pressure ({preemptions} preemptions): {streams:?} vs \
                     {reference:?}"
                );
            }
        }
        Ok(())
    });
}

#[test]
fn prop_no_block_leaks_under_random_traffic() {
    // After any drained workload the pool must be empty again, the
    // high-water mark within capacity, and every completion accounted.
    property("block pool conserved", 15, |rng: &mut Rng| {
        let blocks = 4 + rng.usize_below(8);
        let mut engine = pressured_mock(blocks, 4);
        let n = 1 + rng.usize_below(8);
        for _ in 0..n {
            let len = 1 + rng.usize_below(10);
            let prompt: Vec<i32> = (0..len).map(|_| rng.below(16) as i32).collect();
            engine
                .submit(
                    prompt,
                    SamplingParams {
                        max_tokens: 1 + rng.usize_below(12),
                        priority: rng.below(3) as i32,
                        ..Default::default()
                    },
                )
                .unwrap();
        }
        let done = engine.run_to_completion().unwrap();
        prop_assert!(done.len() == n);
        let s = engine.snapshot();
        // After draining, the only live references are the radix cache's
        // own (one per indexed block), and all of them are cold leaves an
        // allocation could reclaim — nothing is leaked or double-held.
        prop_assert!(
            s.kv_blocks_used == s.prefix_cached_blocks,
            "leaked {} blocks ({} cached)",
            s.kv_blocks_used,
            s.prefix_cached_blocks
        );
        prop_assert!(s.prefix_evictable_blocks == s.prefix_cached_blocks);
        prop_assert!(s.swapped == 0);
        prop_assert!(engine.stats.max_blocks_used <= blocks);
        prop_assert!(engine.stats.resumes == engine.stats.preemptions);
        Ok(())
    });
}

fn native_cfg(kv_blocks: usize) -> NativeModelConfig {
    NativeModelConfig {
        vocab: 32,
        d_model: 32,
        n_layers: 2,
        n_heads: 2,
        d_ff: 64,
        max_seq: 32,
        batch: 2,
        prefill_buckets: vec![4, 8],
        seed: 0x9A6ED,
        threads: 0,
        kv_block_size: 4,
        kv_blocks,
    }
}

#[test]
fn native_preemption_replays_bitwise() {
    // Real transformer math: a 6-block pool (24-token context for two
    // requests that want 5 blocks each) forces swap-out/swap-in of live
    // K/V data. Greedy decoding is argmax over logits, so identical
    // token streams here mean the restored cache reproduced the logits
    // bitwise; the dense FFN keeps every row's math independent of its
    // batch-mates.
    let specs: Vec<Vec<i32>> = vec![vec![3, 7, 11, 2, 5, 9], vec![8, 1, 4, 6, 2, 10]];
    let run = |kv_blocks: usize, policy: PolicyKind| {
        let model = NativeModel::new(native_cfg(kv_blocks), &FfnMode::Dense);
        assert_eq!(model.kv_layout().block_size, 4);
        let mut cfg = EngineConfig::default();
        cfg.scheduler.policy = policy;
        let mut e = InferenceEngine::new(model, cfg);
        let ids: Vec<u64> = specs
            .iter()
            .map(|p| {
                e.submit(
                    p.clone(),
                    SamplingParams { max_tokens: 12, ..Default::default() },
                )
                .unwrap()
            })
            .collect();
        let done = e.run_to_completion().unwrap();
        let streams: Vec<Vec<i32>> = ids
            .iter()
            .map(|id| done.iter().find(|c| c.id == *id).unwrap().tokens.clone())
            .collect();
        (streams, e.stats.preemptions)
    };
    // Reference: auto-sized pool (no pressure, no preemption).
    let (reference, p0) = run(0, PolicyKind::Fifo);
    assert_eq!(p0, 0, "auto pool must not preempt");
    for kind in PolicyKind::all() {
        let (streams, preemptions) = run(6, kind);
        assert!(preemptions > 0, "policy {kind:?}: undersized pool must preempt");
        assert_eq!(
            streams, reference,
            "policy {kind:?}: preemption changed native token streams"
        );
    }
}

#[test]
fn half_prefilled_job_and_stalled_decoder_resolve_via_last_resort() {
    // Deadlock regression: pool 4 blocks x 4 tokens, A = 10-token prompt
    // (prefills 8 + 2; the 2-token tail chunk needs a third block), B =
    // 5-token prompt that decodes past its table as the *sole* decoder.
    // A's job and B's table jointly hold the whole pool; without the
    // last-resort eviction neither can ever proceed and
    // run_to_completion spins forever.
    let specs: Vec<Spec> = vec![
        Spec {
            prompt: vec![1; 10],
            params: SamplingParams { max_tokens: 10, ..Default::default() },
        },
        Spec {
            prompt: vec![2; 5],
            params: SamplingParams { max_tokens: 10, ..Default::default() },
        },
    ];
    let reference = sequential_reference(&specs, 4, 4);
    for mixed in [true, false] {
        let mut cfg = EngineConfig::default();
        cfg.scheduler.mixed = mixed;
        let model = MockModel::new(4, 64, 16, vec![4, 8]).with_kv_layout(4, 4);
        let (streams, preemptions) =
            run_batched(&specs, InferenceEngine::new(model, cfg));
        assert!(preemptions > 0, "mixed={mixed}: breaker must preempt");
        assert_eq!(streams, reference, "mixed={mixed}");
    }
}

#[test]
fn competing_prefills_resolve_via_abort() {
    // Deadlock regression: two 10-token prompts each hold 2 of the 4
    // blocks after their first chunk, and both tail chunks need a third
    // — no decoder exists to swap, so the youngest job must abort back
    // to the queue front and re-prefill once blocks free up.
    let specs: Vec<Spec> = (0..2)
        .map(|i| Spec {
            prompt: vec![1 + i; 10],
            params: SamplingParams { max_tokens: 12, ..Default::default() },
        })
        .collect();
    let reference = sequential_reference(&specs, 4, 4);
    for mixed in [true, false] {
        let mut cfg = EngineConfig::default();
        cfg.scheduler.mixed = mixed;
        let model = MockModel::new(4, 64, 16, vec![4, 8]).with_kv_layout(4, 4);
        let mut engine = InferenceEngine::new(model, cfg);
        let ids: Vec<u64> = specs
            .iter()
            .map(|s| engine.submit(s.prompt.clone(), s.params).unwrap())
            .collect();
        let done = engine.run_to_completion().unwrap();
        assert!(engine.stats.prefill_aborts > 0, "mixed={mixed}: must abort");
        let streams: Vec<Vec<i32>> = ids
            .iter()
            .map(|id| done.iter().find(|c| c.id == *id).unwrap().tokens.clone())
            .collect();
        assert_eq!(streams, reference, "mixed={mixed}");
    }
}

#[test]
fn mixed_planner_overlaps_prefill_with_decode_under_budget() {
    // A token budget still overlaps chunked prefills with decodes; the
    // segregated baseline never does.
    let run = |mixed: bool| {
        let model = MockModel::new(4, 64, 16, vec![4]);
        let mut cfg = EngineConfig::default();
        cfg.scheduler.mixed = mixed;
        cfg.scheduler.max_step_tokens = 8;
        let mut e = InferenceEngine::new(model, cfg);
        for i in 0..4 {
            e.submit(
                vec![1 + i; 12],
                SamplingParams { max_tokens: 12, ..Default::default() },
            )
            .unwrap();
        }
        e.run_to_completion().unwrap();
        (e.stats.mixed_steps, e.stats.decode_steps)
    };
    let (mixed_steps, _) = run(true);
    assert!(mixed_steps > 0, "mixed planner produced no mixed iterations");
    let (segregated_mixed, segregated_decodes) = run(false);
    assert_eq!(segregated_mixed, 0, "segregated planner must never mix");
    assert!(segregated_decodes > 0);
}
