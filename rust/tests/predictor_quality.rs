//! Predictor routing-quality regression (paper §5.3 / Fig 15): on a
//! seeded workload with injected **direction-dependent outliers**, the
//! quantized per-neuron router must catch violations the 1-D norm proxy
//! provably cannot see.
//!
//! The construction (shared with the bench via
//! [`tardis::ffn::compare_predictors`], so the CI-reported numbers and
//! these assertions measure the same workload): every row has the same
//! input norm. The norm gate, once its learned radius covers that norm,
//! folds *every* row — it routes on `‖x‖` alone and is blind to
//! direction. The injected rows are aligned with the most fragile
//! folded `W_up` column, so exactly one neuron's pre-activation leaves
//! its range while the row's norm stays unremarkable. The quantized
//! proxy sees the direction and flags (then fixes) precisely those
//! neurons.

use std::sync::Arc;

use tardis::config::{PredictorKind, TardisFfnConfig};
use tardis::ffn::{compare_predictors, DenseFfn, FoldedFfn, PredictorComparison, Scratch};
use tardis::util::rng::Rng;

const D: usize = 64;
const H: usize = 128;

fn random_dense(seed: u64) -> DenseFfn {
    let mut rng = Rng::new(seed);
    let scale = 1.0 / (D as f64).sqrt();
    DenseFfn::new(
        Arc::new((0..D * H).map(|_| (rng.normal() * scale) as f32).collect()),
        Arc::new((0..H).map(|_| (rng.normal() * 0.05) as f32).collect()),
        Arc::new((0..H * D).map(|_| (rng.normal() * scale) as f32).collect()),
        Arc::new(vec![0.0; D]),
        D,
        H,
    )
}

fn cfg() -> TardisFfnConfig {
    TardisFfnConfig {
        fold_ratio: 0.8,
        linear_lo: -6.0,
        linear_hi: 6.0,
        predictor_threshold: 1.05,
        predictor: PredictorKind::Norm, // compare_predictors sets both kinds
        predictor_bits: 4,
        predictor_group: 32,
        top_k: 8,
    }
}

fn setup(seed: u64) -> PredictorComparison {
    let mut rng = Rng::new(seed ^ 0xD1CE);
    let c = compare_predictors(random_dense(seed), &cfg(), &mut rng);
    // The warmup inside the harness must have taught the norm gate this
    // workload's norm — otherwise the comparison below is not the
    // "warmed gate" scenario it claims to be.
    assert!(
        c.norm_fold.predictor.predicted_radius() >= c.norm_target,
        "warmup must teach the norm gate this workload's norm \
         (radius {} vs target {})",
        c.norm_fold.predictor.predicted_radius(),
        c.norm_target
    );
    c
}

#[test]
fn quantized_router_beats_norm_proxy_on_injected_outliers() {
    let c = setup(0xBEE5);
    let (qn, qq) = (c.norm, c.quantized);
    // same ground truth for both predictors
    assert_eq!(qn.true_oor_rate, qq.true_oor_rate);
    assert!(
        qn.true_oor_rate > 0.0,
        "workload must inject real violations ({qn:?})"
    );
    // the norm proxy folds every row at the learned norm: it misses
    // (nearly) all direction-dependent outliers
    assert!(qn.recall < 0.5, "norm proxy should be blind here: {qn:?}");
    // the quantized per-neuron router catches them, precisely
    assert!(qq.recall > 0.9, "quantized recall: {qq:?}");
    assert!(qq.precision > 0.9, "quantized precision: {qq:?}");
    // and is strictly better on both axes (the acceptance criterion)
    assert!(qq.recall > qn.recall, "recall: {qq:?} vs {qn:?}");
    assert!(qq.precision > qn.precision, "precision: {qq:?} vs {qn:?}");
    // flagging stays sparse — per-neuron routing, not per-row blowout
    assert!(qq.flag_rate < 0.05, "flag rate: {qq:?}");
}

#[test]
fn norm_gate_trades_recall_for_fallback_before_warmup() {
    // Before any learning, the same workload sits beyond the provable
    // radius: a cold norm gate falls back on every row — perfect
    // recall, terrible precision (it runs ~everything dense). This is
    // the fallback-cost side of the precision/recall tradeoff the bench
    // reports.
    let c = setup(0xBEE5);
    let f_cold = FoldedFfn::new(random_dense(0xBEE5), &cfg());
    let mut scratch = Scratch::new();
    let q = f_cold.routing_quality(&mut scratch, &c.workload, c.rows);
    assert!(q.recall > 0.95, "cold norm gate flags everything: {q:?}");
    assert!(q.flag_rate > 0.95, "{q:?}");
    assert!(
        q.precision < 0.2,
        "whole-row fallback wastes almost every flag: {q:?}"
    );
}

#[test]
fn fixed_outliers_track_the_reference_end_to_end() {
    let mut c = setup(0xFACE);
    let mut scratch = Scratch::new();
    // Quantized route: every injected row is fixed per neuron (1 flag
    // <= top_k), nothing falls back, and the output stays within fold
    // roundoff of the exact partially-linear reference.
    let got = c.quant_fold.forward(None, &mut scratch, &c.workload, c.rows);
    let want = c
        .quant_fold
        .reference
        .forward(None, &mut scratch, &c.workload, c.rows);
    for (i, (g, w)) in got.iter().zip(&want).enumerate() {
        assert!(
            (g - w).abs() <= 5e-3 * w.abs().max(1.0),
            "elem {i}: quantized {g} vs reference {w}"
        );
    }
    let tele = c.quant_fold.telemetry;
    assert_eq!(tele.fallback_rows, 0, "fixing should replace fallback");
    assert_eq!(tele.folded_rows, c.rows as u64);
    let n_injected = (c.rows / 4) as u64;
    assert!(
        tele.fixed_neurons >= n_injected,
        "each injected outlier costs at least one fix: {} < {n_injected}",
        tele.fixed_neurons
    );
    // The warmed norm gate folds the same batch wholesale — no new
    // fallback, no fixes: the outliers silently take the surrogate
    // path. That is exactly the blindness the quantized router removes.
    let before = c.norm_fold.telemetry;
    let y = c.norm_fold.forward(None, &mut scratch, &c.workload, c.rows);
    scratch.give(y);
    assert_eq!(c.norm_fold.telemetry.fallback_rows, before.fallback_rows);
    assert_eq!(c.norm_fold.telemetry.fixed_neurons, 0);
    assert_eq!(
        c.norm_fold.telemetry.folded_rows,
        before.folded_rows + c.rows as u64
    );
}
