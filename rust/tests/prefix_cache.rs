//! Integration tests for prefix sharing: radix-cache hits must skip
//! prefill compute for the shared prefix, copy-on-write must isolate
//! divergent tails, and sharing must never perturb token streams —
//! across every scheduler policy, both planners, preemption pressure,
//! and (bitwise, via greedy argmax) the native transformer backend.

use tardis::config::{FfnMode, NativeModelConfig};
use tardis::coordinator::engine_loop::{EngineConfig, InferenceEngine};
use tardis::coordinator::model::{MockModel, NativeModel};
use tardis::coordinator::request::SamplingParams;
use tardis::coordinator::scheduler::PolicyKind;
use tardis::coordinator::StepModel;
use tardis::prop_assert;
use tardis::testing::property;
use tardis::util::rng::Rng;

#[derive(Clone)]
struct Spec {
    prompt: Vec<i32>,
    params: SamplingParams,
}

fn mock_engine(blocks: usize, block_size: usize, cfg: EngineConfig) -> InferenceEngine<MockModel> {
    let model = MockModel::new(4, 64, 16, vec![4, 8]).with_kv_layout(blocks, block_size);
    InferenceEngine::new(model, cfg)
}

/// Ground truth: one request at a time with the prefix cache OFF, over
/// the same pressured layout (so context clamping matches).
fn sequential_unshared(specs: &[Spec], blocks: usize, block_size: usize) -> Vec<Vec<i32>> {
    let cfg = EngineConfig { prefix_cache: false, ..Default::default() };
    let mut engine = mock_engine(blocks, block_size, cfg);
    let out = specs
        .iter()
        .map(|s| {
            engine
                .generate_sequential(s.prompt.clone(), s.params)
                .unwrap()
                .tokens
        })
        .collect();
    assert_eq!(
        engine.stats.preemptions, 0,
        "a lone request must never be preempted"
    );
    out
}

#[test]
fn shared_prompts_replay_identically_across_policies_and_planners() {
    // Six requests sharing an 8-token prefix (plus distinct tails) over
    // 6 blocks x 4 tokens and 4 slots: the pool forces preemptions and
    // cold-leaf cache evictions while later admissions hit the cached
    // trunk — no combination of policy x planner may change any stream
    // relative to an unshared, uncontended run.
    let specs: Vec<Spec> = (0..6)
        .map(|i| {
            let mut prompt = vec![9, 9, 9, 9, 3, 3, 3, 3];
            prompt.extend(std::iter::repeat(1 + i).take(3));
            Spec {
                prompt,
                params: SamplingParams { max_tokens: 8, ..Default::default() },
            }
        })
        .collect();
    let reference = sequential_unshared(&specs, 6, 4);
    let mut total_preemptions = 0;
    let mut total_hits = 0;
    for kind in PolicyKind::all() {
        for mixed in [true, false] {
            let mut cfg = EngineConfig::default();
            cfg.scheduler.policy = kind;
            cfg.scheduler.mixed = mixed;
            let mut engine = mock_engine(6, 4, cfg);
            assert!(engine.prefix_sharing());
            let ids: Vec<u64> = specs
                .iter()
                .map(|s| engine.submit(s.prompt.clone(), s.params).unwrap())
                .collect();
            let done = engine.run_to_completion().unwrap();
            let streams: Vec<Vec<i32>> = ids
                .iter()
                .map(|id| done.iter().find(|c| c.id == *id).unwrap().tokens.clone())
                .collect();
            assert_eq!(
                streams, reference,
                "policy {kind:?} (mixed={mixed}) diverged with sharing on"
            );
            total_preemptions += engine.stats.preemptions;
            total_hits += engine.stats.prefix_hit_tokens;
        }
    }
    assert!(total_preemptions > 0, "pool pressure must preempt somewhere");
    assert!(total_hits > 0, "shared prompts must hit the prefix cache");
}

#[test]
fn cache_hit_skips_prefill_compute_for_the_shared_prefix() {
    // Ample pool: a 14-token prompt caches 3 full blocks; re-submitting
    // the identical prompt must prefill ONLY the 2-token tail (a single
    // chunk at position 12) and report 12 hit tokens on the completion.
    let mut engine = mock_engine(16, 4, EngineConfig::default());
    let prompt: Vec<i32> = (0..14).collect();
    let params = SamplingParams { max_tokens: 6, ..Default::default() };
    engine.submit(prompt.clone(), params).unwrap();
    let first = engine.run_to_completion().unwrap();
    assert_eq!(first[0].prefix_hit_tokens, 0, "cold cache cannot hit");
    let mark = engine.model.prefill_log.len();

    engine.submit(prompt, params).unwrap();
    let second = engine.run_to_completion().unwrap();
    let tail = &engine.model.prefill_log[mark..];
    assert_eq!(tail.len(), 1, "hit-covered tokens must not be prefilled");
    assert_eq!(tail[0].1, 12, "the lone suffix chunk starts at the hit length");
    assert_eq!(second[0].prefix_hit_tokens, 12);
    assert_eq!(second[0].tokens, first[0].tokens);
    assert_eq!(engine.stats.prefix_hit_tokens, 12);
    assert_eq!(engine.stats.prefix_shared_blocks, 3);
    assert_eq!(engine.stats.cow_copies, 0, "full-block hit needs no copy");
}

#[test]
fn wedged_cache_trunk_cannot_deadlock_the_pool() {
    // Regression: a live table that shares a trie *descendant* keeps the
    // rc-1 trunk above it out of the all-free evictable set, and with a
    // single starved prefill the abort breaker (which wants two) never
    // fires — before the last-resort cache prune this layout could idle
    // the pool forever. 7 blocks x 2 tokens: r1 caches a trunk, r2 hits
    // 4 of its 5 prompt tokens (COW tail) and then needs nearly the
    // whole pool for its long unique suffix.
    let specs = [
        Spec {
            prompt: vec![9, 9, 9, 9, 9],
            params: SamplingParams { max_tokens: 3, ..Default::default() },
        },
        Spec {
            prompt: vec![9, 9, 9, 9, 3, 3, 3, 3, 7, 7, 6],
            params: SamplingParams { max_tokens: 2, ..Default::default() },
        },
    ];
    let reference = sequential_unshared(&specs, 7, 2);
    let mut engine = mock_engine(7, 2, EngineConfig::default());
    let ids: Vec<u64> = specs
        .iter()
        .map(|s| engine.submit(s.prompt.clone(), s.params).unwrap())
        .collect();
    let mut steps = 0usize;
    while !engine.is_idle() {
        engine.step().unwrap();
        steps += 1;
        assert!(steps < 10_000, "engine made no progress: pool is wedged");
    }
    let done = engine.take_completions();
    let streams: Vec<Vec<i32>> = ids
        .iter()
        .map(|id| done.iter().find(|c| c.id == *id).unwrap().tokens.clone())
        .collect();
    assert_eq!(streams, reference, "prune breaker must not perturb streams");
    let s = engine.snapshot();
    assert_eq!(
        s.kv_blocks_used, s.prefix_cached_blocks,
        "a drained engine may hold blocks only through the cache"
    );
}

fn native_cfg() -> NativeModelConfig {
    NativeModelConfig {
        vocab: 32,
        d_model: 32,
        n_layers: 2,
        n_heads: 2,
        d_ff: 64,
        max_seq: 32,
        batch: 2,
        prefill_buckets: vec![4, 8],
        seed: 0x9A6ED,
        threads: 0,
        kv_block_size: 4,
        kv_blocks: 0, // auto-sized: no pressure, isolate the sharing math
    }
}

#[test]
fn native_cow_divergence_is_bitwise_identical_to_unshared_runs() {
    // Real transformer math. A caches 2 full blocks; B shares 6 tokens
    // — a partial hit into A's second block — so admission must COW
    // that block before B's suffix lands in it. Greedy decoding is
    // argmax over logits, so stream equality with the unshared engine
    // means reads through shared blocks and the copied tail reproduced
    // the logits bitwise (per-row kernel math is independent of chunk
    // shape and batch-mates).
    let a: Vec<i32> = vec![3, 7, 11, 2, 5, 9, 12, 8, 1];
    let b: Vec<i32> = vec![3, 7, 11, 2, 5, 9, 2, 2, 4];
    let params = SamplingParams { max_tokens: 10, ..Default::default() };
    let run = |sharing: bool| {
        let model = NativeModel::new(native_cfg(), &FfnMode::Dense);
        assert_eq!(model.kv_layout().block_size, 4);
        let cfg = EngineConfig { prefix_cache: sharing, ..Default::default() };
        let mut e = InferenceEngine::new(model, cfg);
        assert_eq!(e.prefix_sharing(), sharing);
        // Drain between submissions so B's admission always sees A's
        // blocks in the cache (when sharing is on).
        let sa = e.generate_sequential(a.clone(), params).unwrap().tokens;
        let sb = e.generate_sequential(b.clone(), params).unwrap().tokens;
        (sa, sb, e.stats.clone())
    };
    let (ref_a, ref_b, off) = run(false);
    assert_eq!(off.prefix_hit_tokens, 0);
    let (shared_a, shared_b, on) = run(true);
    assert_eq!(shared_a, ref_a, "first request has nothing to share");
    assert_eq!(
        shared_b, ref_b,
        "COW divergence changed the native token stream"
    );
    assert_eq!(on.prefix_hit_tokens, 6, "4 full-block + 2 partial-tail tokens");
    assert_eq!(on.prefix_shared_blocks, 2);
    assert_eq!(on.cow_copies, 1, "the partial tail block must be copied");
}

#[test]
fn native_full_resubmit_skips_all_but_one_prefill_token() {
    // Identical re-submission: 9 tokens cache 2 full blocks, so the
    // second run hits 8 tokens with no COW (the 9th must still run
    // prefill — the sampler needs its logits) and decodes identically.
    let prompt: Vec<i32> = vec![3, 7, 11, 2, 5, 9, 12, 8, 1];
    let params = SamplingParams { max_tokens: 10, ..Default::default() };
    let model = NativeModel::new(native_cfg(), &FfnMode::Dense);
    let mut e = InferenceEngine::new(model, EngineConfig::default());
    let first = e.generate_sequential(prompt.clone(), params).unwrap().tokens;
    let again = e.generate_sequential(prompt, params).unwrap().tokens;
    assert_eq!(again, first, "cache hit changed a native stream");
    assert_eq!(e.stats.prefix_hit_tokens, 8);
    assert_eq!(e.stats.cow_copies, 0);
}

#[test]
fn prop_sharing_preserves_streams_and_conserves_blocks() {
    // Random overlapping traffic (prompts drawn from a few shared
    // prefix templates plus random tails) over random undersized pools:
    // with sharing on, every policy reproduces the unshared sequential
    // reference, and after draining the pool holds exactly the cache's
    // blocks — all of them reclaimable.
    property("prefix sharing invariance", 12, |rng: &mut Rng| {
        let blocks = 5 + rng.usize_below(4);
        let block_size = 4;
        let templates: [&[i32]; 3] = [&[], &[9, 9, 9, 9], &[9, 9, 9, 9, 3, 3, 3, 3]];
        let n = 2 + rng.usize_below(4);
        let specs: Vec<Spec> = (0..n)
            .map(|_| {
                let mut prompt = templates[rng.usize_below(3)].to_vec();
                let tail = 1 + rng.usize_below(5);
                prompt.extend((0..tail).map(|_| rng.below(16) as i32));
                let params = SamplingParams {
                    temperature: if rng.bool(0.5) { 0.0 } else { 0.8 },
                    max_tokens: 1 + rng.usize_below(6),
                    seed: rng.next_u64(),
                    priority: rng.below(5) as i32,
                    ..Default::default()
                };
                Spec { prompt, params }
            })
            .collect();
        let reference = sequential_unshared(&specs, blocks, block_size);
        for kind in PolicyKind::all() {
            for mixed in [true, false] {
                let mut cfg = EngineConfig::default();
                cfg.scheduler.policy = kind;
                cfg.scheduler.mixed = mixed;
                let mut engine = mock_engine(blocks, block_size, cfg);
                let ids: Vec<u64> = specs
                    .iter()
                    .map(|s| engine.submit(s.prompt.clone(), s.params).unwrap())
                    .collect();
                let done = engine.run_to_completion().unwrap();
                let streams: Vec<Vec<i32>> = ids
                    .iter()
                    .map(|id| {
                        done.iter().find(|c| c.id == *id).unwrap().tokens.clone()
                    })
                    .collect();
                prop_assert!(
                    streams == reference,
                    "policy {kind:?} (mixed={mixed}) diverged with sharing: \
                     {streams:?} vs {reference:?}"
                );
                let s = engine.snapshot();
                prop_assert!(
                    s.kv_blocks_used == s.prefix_cached_blocks,
                    "leaked {} blocks ({} cached)",
                    s.kv_blocks_used,
                    s.prefix_cached_blocks
                );
                prop_assert!(s.prefix_evictable_blocks == s.prefix_cached_blocks);
                prop_assert!(s.swapped == 0);
                prop_assert!(engine.stats.max_blocks_used <= blocks);
                prop_assert!(engine.stats.resumes == engine.stats.preemptions);
            }
        }
        Ok(())
    });
}
