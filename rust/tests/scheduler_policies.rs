//! Std-only integration tests for the StepPlan scheduler pipeline:
//! batching invariance across scheduling policies (the serving-layer
//! contract: a request's token stream never depends on the policy in
//! force or on its batch-mates), genuine multi-prefill interleaving, and
//! the decode starvation guard.

use tardis::coordinator::engine_loop::{EngineConfig, InferenceEngine};
use tardis::coordinator::model::MockModel;
use tardis::coordinator::request::SamplingParams;
use tardis::coordinator::scheduler::{PolicyKind, SchedulerConfig};
use tardis::prop_assert;
use tardis::testing::property;
use tardis::util::rng::Rng;

fn mock() -> MockModel {
    MockModel::new(4, 64, 16, vec![4, 8])
}

#[derive(Clone)]
struct Spec {
    prompt: Vec<i32>,
    params: SamplingParams,
}

fn random_specs(rng: &mut Rng) -> Vec<Spec> {
    let n = 1 + rng.usize_below(6);
    (0..n)
        .map(|_| {
            let len = 1 + rng.usize_below(20);
            let prompt: Vec<i32> =
                (0..len).map(|_| rng.below(16) as i32).collect();
            let params = SamplingParams {
                temperature: if rng.bool(0.5) { 0.0 } else { 0.8 },
                top_k: if rng.bool(0.5) { 0 } else { 1 + rng.usize_below(8) },
                max_tokens: 1 + rng.usize_below(8),
                stop_token: None,
                seed: rng.next_u64(),
                priority: rng.below(5) as i32,
                ..Default::default()
            };
            Spec { prompt, params }
        })
        .collect()
}

/// Submit every spec up front, run to completion, return token streams
/// in submission order.
fn run_batched(specs: &[Spec], cfg: EngineConfig) -> Vec<Vec<i32>> {
    let mut e = InferenceEngine::new(mock(), cfg);
    let ids: Vec<u64> = specs
        .iter()
        .map(|s| e.submit(s.prompt.clone(), s.params).unwrap())
        .collect();
    let done = e.run_to_completion().unwrap();
    ids.iter()
        .map(|id| {
            done.iter()
                .find(|c| c.id == *id)
                .expect("request completed")
                .tokens
                .clone()
        })
        .collect()
}

#[test]
fn prop_batching_invariance_across_policies() {
    property("token streams are policy-invariant", 40, |rng| {
        let specs = random_specs(rng);
        // Reference: the HF-like sequential baseline, one request at a
        // time on an otherwise idle engine (occupancy 1, no batch-mates).
        let mut seq = InferenceEngine::new(mock(), EngineConfig::default());
        let mut reference = Vec::new();
        for s in &specs {
            let c = seq
                .generate_sequential(s.prompt.clone(), s.params)
                .unwrap();
            reference.push(c.tokens);
        }
        // Every shipped policy, multi-prefill config.
        for kind in PolicyKind::all() {
            let mut cfg = EngineConfig::default();
            cfg.scheduler.policy = kind;
            let got = run_batched(&specs, cfg);
            prop_assert!(
                got == reference,
                "policy {kind:?} changed outputs: {got:?} vs {reference:?}"
            );
        }
        // And the seed-equivalent single-prefill FIFO config.
        let cfg = EngineConfig {
            scheduler: SchedulerConfig::single_prefill(),
            ..Default::default()
        };
        let got = run_batched(&specs, cfg);
        prop_assert!(got == reference,
                     "single-prefill config changed outputs");
        Ok(())
    });
}

#[test]
fn concurrent_prefills_genuinely_interleave() {
    // Two 12-token prompts over 4-token chunks with the default config
    // (2 concurrent prefills, 2 chunks/iteration): their chunks must
    // alternate rather than one prompt running start-to-finish first.
    let model = MockModel::new(4, 64, 16, vec![4]);
    let mut e = InferenceEngine::new(model, EngineConfig::default());
    e.submit(vec![1; 12],
             SamplingParams { max_tokens: 1, ..Default::default() })
        .unwrap();
    e.submit(vec![2; 12],
             SamplingParams { max_tokens: 1, ..Default::default() })
        .unwrap();
    let done = e.run_to_completion().unwrap();
    assert_eq!(done.len(), 2);
    assert_eq!(e.stats.max_concurrent_prefills, 2,
               "two prefill jobs must be in flight simultaneously");
    assert_eq!(e.model.max_planned_prefills, 2,
               "plans must carry chunks for two prompts at once");
    let log = &e.model.prefill_log;
    assert_eq!(log.len(), 6, "3 chunks per prompt: {log:?}");
    let slots: Vec<usize> = log.iter().map(|&(s, _)| s).collect();
    let pos: Vec<usize> = log.iter().map(|&(_, p)| p).collect();
    assert_ne!(slots[0], slots[1],
               "first two chunks belong to different prompts: {log:?}");
    assert_eq!(pos, vec![0, 0, 4, 4, 8, 8],
               "chunks advance round-robin: {log:?}");
}

#[test]
fn starvation_guard_bounds_prefill_only_iterations() {
    let mut cfg = EngineConfig::default();
    cfg.queue_capacity = 128;
    cfg.scheduler.max_consecutive_prefills = 3;
    let model = MockModel::new(4, 256, 16, vec![4]);
    let mut e = InferenceEngine::new(model, cfg);
    // Deep backlog of chunky prompts so prefill work never runs out
    // while requests decode.
    for i in 0..24 {
        e.submit(vec![1 + (i % 10), 2, 3, 4, 5, 6, 7, 8, 9, 10, 11, 12],
                 SamplingParams { max_tokens: 30, ..Default::default() })
            .unwrap();
    }
    let mut consecutive = 0usize;
    let mut decode_with_backlog = false;
    while !e.is_idle() {
        let had_active = e.snapshot().active_slots > 0;
        let out = e.step().unwrap();
        if out.prefill_chunks > 0 && out.decoded_slots == 0 {
            if had_active {
                consecutive += 1;
                assert!(
                    consecutive <= 3,
                    "{consecutive} consecutive prefill-only iterations \
                     exceed the guard of 3"
                );
            } else {
                consecutive = 0;
            }
        } else {
            if out.decoded_slots > 0 && e.snapshot().queue_depth > 0 {
                decode_with_backlog = true;
            }
            consecutive = 0;
        }
    }
    assert!(decode_with_backlog,
            "decodes must interleave while the queue is still deep");
    assert_eq!(e.take_completions().len(), 24);
}

#[test]
fn priority_policy_admits_urgent_requests_first() {
    let mut cfg = EngineConfig::default();
    cfg.scheduler.policy = PolicyKind::Priority;
    cfg.scheduler.max_concurrent_prefills = 1; // serialize admissions
    cfg.scheduler.chunk_budget = 1;
    let model = MockModel::new(1, 64, 16, vec![4]);
    let mut e = InferenceEngine::new(model, cfg);
    let low = e
        .submit(vec![1; 8],
                SamplingParams { max_tokens: 1, priority: 0,
                                 ..Default::default() })
        .unwrap();
    let high = e
        .submit(vec![2; 8],
                SamplingParams { max_tokens: 1, priority: 9,
                                 ..Default::default() })
        .unwrap();
    let done = e.run_to_completion().unwrap();
    assert_eq!(done[0].id, high,
               "high-priority request finishes first despite arriving later");
    assert_eq!(done[1].id, low);
    assert!(done[1].queue_ms >= done[0].queue_ms,
            "low-priority request waited at least as long in the queue");
}
