//! Self-speculative decoding invariants, end to end through the
//! engine:
//!
//! - greedy speculative streams are bitwise identical to plain decode
//!   for every draft window k ∈ {1,2,4,8}, every scheduler policy and
//!   both planners (mixed and segregated), with drafts that genuinely
//!   diverge from the verifier;
//! - rejected draft tails roll the paged KV back without leaking or
//!   corrupting blocks, including under pool pressure with preemption
//!   (block-refcount conservation: an idle engine holds zero blocks
//!   once prefix sharing is off);
//! - a reject-then-preempt-then-resume sequence replays bitwise-equal
//!   to the sequential reference;
//! - the NativeModel backend (dense and TARDIS modes) produces the
//!   same stream with and without speculation.

use tardis::config::{FfnMode, NativeModelConfig, TardisFfnConfig};
use tardis::coordinator::engine_loop::{EngineConfig, InferenceEngine};
use tardis::coordinator::model::{MockModel, NativeModel};
use tardis::coordinator::request::SamplingParams;
use tardis::coordinator::scheduler::PolicyKind;
use tardis::prop_assert;
use tardis::testing::property;
use tardis::util::rng::Rng;

#[derive(Clone)]
struct Spec {
    prompt: Vec<i32>,
    params: SamplingParams,
}

fn random_specs(rng: &mut Rng) -> Vec<Spec> {
    let n = 1 + rng.usize_below(5);
    (0..n)
        .map(|_| {
            let len = 1 + rng.usize_below(16);
            let prompt: Vec<i32> =
                (0..len).map(|_| rng.below(16) as i32).collect();
            let params = SamplingParams {
                // Mostly greedy (the speculative path), with some
                // sampled requests mixed in to prove they bypass
                // speculation without disturbing their RNG streams.
                temperature: if rng.bool(0.75) { 0.0 } else { 0.8 },
                max_tokens: 1 + rng.usize_below(12),
                seed: rng.next_u64(),
                priority: rng.below(4) as i32,
                ..Default::default()
            };
            Spec { prompt, params }
        })
        .collect()
}

fn run_engine(
    specs: &[Spec],
    cfg: EngineConfig,
    miss_period: usize,
) -> (Vec<Vec<i32>>, InferenceEngine<MockModel>) {
    let model = MockModel::new(4, 64, 16, vec![4, 8]).with_draft_misses(miss_period);
    let mut e = InferenceEngine::new(model, cfg);
    let ids: Vec<u64> = specs
        .iter()
        .map(|s| e.submit(s.prompt.clone(), s.params).unwrap())
        .collect();
    let done = e.run_to_completion().unwrap();
    let streams = ids
        .iter()
        .map(|id| {
            done.iter()
                .find(|c| c.id == *id)
                .expect("request completed")
                .tokens
                .clone()
        })
        .collect();
    (streams, e)
}

#[test]
fn prop_speculative_streams_bitwise_identical() {
    property("speculation never changes a token stream", 25, |rng| {
        let specs = random_specs(rng);
        // Drafts miss every 3rd or 4th position: both full-window
        // acceptance and mid-window rejection occur.
        let miss = 3 + rng.usize_below(2);
        let (reference, _) =
            run_engine(&specs, EngineConfig::default(), miss);
        for k in [1usize, 2, 4, 8] {
            for kind in PolicyKind::all() {
                for mixed in [true, false] {
                    let mut cfg = EngineConfig {
                        speculate_k: k,
                        ..Default::default()
                    };
                    cfg.scheduler.policy = kind;
                    cfg.scheduler.mixed = mixed;
                    let (got, e) = run_engine(&specs, cfg, miss);
                    prop_assert!(
                        got == reference,
                        "k={k} policy {kind:?} mixed={mixed} changed \
                         outputs: {got:?} vs {reference:?}"
                    );
                    // A greedy request only opens a draft window while
                    // at least 2 tokens remain after the verify's own
                    // (max_tokens >= 3: prefill emits the first token).
                    let has_room = specs.iter().any(|s| {
                        s.params.temperature == 0.0 && s.params.max_tokens >= 3
                    });
                    prop_assert!(
                        !has_room || e.stats.spec_steps > 0,
                        "speculation never engaged at k={k}"
                    );
                }
            }
        }
        Ok(())
    });
}

#[test]
fn prop_speculation_under_kv_pressure_conserves_blocks() {
    property("rollback conserves blocks under preemption", 20, |rng| {
        let specs: Vec<Spec> = (0..3)
            .map(|i| Spec {
                prompt: vec![1 + i; 7 + rng.usize_below(4)],
                params: SamplingParams {
                    max_tokens: 8 + rng.usize_below(6),
                    ..Default::default()
                },
            })
            .collect();
        let run = |k: usize| {
            // 7 blocks of 4 tokens across 3 growing requests: the pool
            // oversubscribes and someone gets preempted mid-decode.
            let model = MockModel::new(2, 64, 16, vec![4, 8])
                .with_kv_layout(7, 4)
                .with_draft_misses(3);
            let cfg = EngineConfig {
                prefix_cache: false,
                speculate_k: k,
                ..Default::default()
            };
            let mut e = InferenceEngine::new(model, cfg);
            let ids: Vec<u64> = specs
                .iter()
                .map(|s| e.submit(s.prompt.clone(), s.params).unwrap())
                .collect();
            let done = e.run_to_completion().unwrap();
            let streams: Vec<Vec<i32>> = ids
                .iter()
                .map(|id| {
                    done.iter().find(|c| c.id == *id).unwrap().tokens.clone()
                })
                .collect();
            (streams, e)
        };
        let (reference, _) = run(0);
        for k in [1usize, 4, 8] {
            let (got, e) = run(k);
            prop_assert!(
                got == reference,
                "k={k} changed outputs under pressure"
            );
            prop_assert!(
                e.snapshot().kv_blocks_used == 0,
                "k={k}: idle engine still holds {} KV blocks",
                e.snapshot().kv_blocks_used
            );
        }
        Ok(())
    });
}

#[test]
fn reject_then_preempt_then_resume_replays_bitwise() {
    // Satellite regression: a rejected draft tail truncates the paged
    // KV, then pool pressure preempts the slot, then it resumes — the
    // replayed stream must equal the sequential reference exactly.
    let prompts: Vec<Vec<i32>> = vec![vec![3; 9], vec![5; 9]];
    let params = SamplingParams { max_tokens: 12, ..Default::default() };
    let reference: Vec<Vec<i32>> = {
        let model = MockModel::new(2, 64, 16, vec![4, 8]);
        let cfg = EngineConfig { prefix_cache: false, ..Default::default() };
        let mut e = InferenceEngine::new(model, cfg);
        prompts
            .iter()
            .map(|p| e.generate_sequential(p.clone(), params).unwrap().tokens)
            .collect()
    };
    // Misses every 2nd position keep rejecting tails; a 6-block pool
    // under two 12-token tails forces preemption between verify steps.
    let model = MockModel::new(2, 64, 16, vec![4, 8])
        .with_kv_layout(6, 4)
        .with_draft_misses(2);
    let cfg = EngineConfig {
        prefix_cache: false,
        speculate_k: 4,
        ..Default::default()
    };
    let mut e = InferenceEngine::new(model, cfg);
    let ids: Vec<u64> = prompts
        .iter()
        .map(|p| e.submit(p.clone(), params).unwrap())
        .collect();
    let done = e.run_to_completion().unwrap();
    assert!(e.stats.spec_steps > 0, "speculation never engaged");
    assert!(
        e.stats.spec_accepted < e.stats.spec_drafted,
        "draft misses must reject some tokens"
    );
    assert!(e.stats.preemptions > 0, "pool pressure must preempt");
    assert_eq!(e.stats.resumes, e.stats.preemptions);
    assert_eq!(e.snapshot().kv_blocks_used, 0, "blocks leaked");
    for (i, id) in ids.iter().enumerate() {
        let c = done.iter().find(|c| c.id == *id).unwrap();
        assert_eq!(
            c.tokens, reference[i],
            "reject+preempt+resume diverged from the sequential reference"
        );
    }
}

#[test]
fn native_backend_streams_survive_speculation() {
    let cfg = NativeModelConfig {
        vocab: 32,
        d_model: 32,
        n_layers: 1,
        n_heads: 2,
        d_ff: 64,
        max_seq: 32,
        batch: 2,
        prefill_buckets: vec![4],
        seed: 5,
        threads: 0,
        kv_block_size: 8,
        kv_blocks: 0,
    };
    for mode in [
        FfnMode::Dense,
        FfnMode::Tardis(TardisFfnConfig::with_ratio(0.8)),
    ] {
        let run = |k: usize| {
            let model = NativeModel::new(cfg.clone(), &mode);
            let ecfg = EngineConfig {
                speculate_k: k,
                prefix_cache: false,
                ..Default::default()
            };
            let mut e = InferenceEngine::new(model, ecfg);
            let params =
                SamplingParams { max_tokens: 12, ..Default::default() };
            let c = e.generate_sequential(vec![3, 7, 11, 2, 5], params).unwrap();
            (c.tokens, e.stats.spec_steps)
        };
        let (reference, spec_steps) = run(0);
        assert_eq!(spec_steps, 0);
        for k in [1usize, 2, 4] {
            let (got, spec_steps) = run(k);
            assert!(spec_steps > 0, "k={k}: speculation never engaged");
            assert_eq!(
                got, reference,
                "k={k}: speculation changed the native stream"
            );
        }
    }
}

#[test]
fn adaptive_windows_keep_streams_identical() {
    let specs = vec![
        Spec {
            prompt: vec![2, 9, 4],
            params: SamplingParams { max_tokens: 14, ..Default::default() },
        },
        Spec {
            prompt: vec![8, 1],
            params: SamplingParams { max_tokens: 10, ..Default::default() },
        },
    ];
    let (reference, _) = run_engine(&specs, EngineConfig::default(), 2);
    let cfg = EngineConfig {
        speculate_k: 8,
        speculate_adaptive: true,
        ..Default::default()
    };
    let (got, e) = run_engine(&specs, cfg, 2);
    assert_eq!(got, reference, "adaptive speculation changed outputs");
    let acc = e.stats.spec_acceptance().unwrap();
    assert!(acc < 1.0, "miss period 2 must reject drafts, acceptance {acc}");
}
