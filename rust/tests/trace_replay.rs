//! Integration tests over the committed overload trace fixture
//! (`tests/data/traces/overload.jsonl`): bitwise JSONL round-trip,
//! bitwise-identical replays across policies and planners, and the
//! goodput ordering the CI lane gates on (edf strictly beats fifo on
//! this trace under the serving-lane overload ladder).
//!
//! The fixture is load-only: it is never regenerated here, so the
//! assertions are independent of libm differences across hosts. To
//! rebuild it after changing `TraceSpec::overload_preset()`, run
//! `tardis bench-trace --preset overload --trace-out <path>` and commit
//! the new file alongside updated expectations.

use std::path::PathBuf;

use tardis::coordinator::engine_loop::{EngineConfig, InferenceEngine};
use tardis::coordinator::model::MockModel;
use tardis::coordinator::queue::OverloadPolicy;
use tardis::coordinator::scheduler::PolicyKind;
use tardis::testing::trace::{
    dump_jsonl, load_jsonl, replay, ReplayConfig, ReplayReport, TraceEvent,
};

fn fixture_text() -> String {
    let path = PathBuf::from(env!("CARGO_MANIFEST_DIR"))
        .join("tests/data/traces/overload.jsonl");
    std::fs::read_to_string(&path)
        .unwrap_or_else(|e| panic!("read {}: {e}", path.display()))
}

fn fixture_events() -> Vec<TraceEvent> {
    load_jsonl(&fixture_text()).expect("fixture parses")
}

/// Mirror of the `bench-trace` engine: 4 decode slots, ample KV, the
/// standard chunk buckets, and the serving queue depth.
fn engine(policy: PolicyKind, mixed: bool) -> InferenceEngine<MockModel> {
    let mut cfg = EngineConfig { queue_capacity: 64, ..Default::default() };
    cfg.scheduler.policy = policy;
    cfg.scheduler.mixed = mixed;
    InferenceEngine::new(MockModel::new(4, 256, 256, vec![16, 64]), cfg)
}

/// The CI-lane replay knobs: overload ladder degrading tier 0 at 50 %
/// queue pressure and shedding it at 90 %, 1 ms per engine step.
fn ci_config() -> ReplayConfig {
    ReplayConfig {
        overload: OverloadPolicy { degrade_at: 0.5, shed_at: 0.9, tier_max: 0 },
        step_cost_us: 1_000,
        seed: 0,
    }
}

fn run(policy: PolicyKind, mixed: bool, cfg: &ReplayConfig) -> ReplayReport {
    let events = fixture_events();
    replay(&mut engine(policy, mixed), &events, cfg).expect("replay")
}

#[test]
fn fixture_round_trips_bitwise() {
    let text = fixture_text();
    let events = load_jsonl(&text).expect("fixture parses");
    assert!(!events.is_empty(), "fixture must not be empty");
    assert_eq!(dump_jsonl(&events), text, "dump(load(fixture)) == fixture");
    assert!(
        events.windows(2).all(|w| w[0].arrival_us <= w[1].arrival_us),
        "fixture sorted by arrival"
    );
    let tiers: std::collections::BTreeSet<usize> =
        events.iter().map(|e| e.tier).collect();
    assert!(tiers.len() >= 2, "fixture mixes SLO tiers, got {tiers:?}");
    assert!(
        events
            .iter()
            .filter(|e| e.tier == 1)
            .all(|e| e.ttft_deadline_ms.is_some() && e.tpot_deadline_ms.is_some()),
        "interactive tier carries deadlines"
    );
    assert!(
        events
            .iter()
            .filter(|e| e.tier == 0)
            .all(|e| e.ttft_deadline_ms.is_none()),
        "bulk tier is deadline-free"
    );
}

#[test]
fn replays_are_bitwise_identical_across_policies_and_planners() {
    let cfg = ci_config();
    for policy in [PolicyKind::Fifo, PolicyKind::Edf] {
        for mixed in [true, false] {
            let a = run(policy, mixed, &cfg);
            let b = run(policy, mixed, &cfg);
            assert_eq!(
                a.outcomes,
                b.outcomes,
                "{policy:?} mixed={mixed} replay must be bitwise reproducible"
            );
            assert_eq!(a.makespan_us, b.makespan_us);
            assert_eq!(a.tiers, b.tiers);
        }
    }
}

#[test]
fn token_streams_are_policy_invariant_on_the_fixture() {
    // Scheduling order changes latency, never content: every admitted
    // request's token stream matches across fifo and edf. Run without
    // the ladder so both policies admit the identical request set.
    let cfg = ReplayConfig::default();
    let fifo = run(PolicyKind::Fifo, true, &cfg);
    let edf = run(PolicyKind::Edf, true, &cfg);
    assert_eq!(fifo.outcomes.len(), edf.outcomes.len());
    for (f, e) in fifo.outcomes.iter().zip(edf.outcomes.iter()) {
        assert_eq!(f.id, e.id);
        assert!(f.admitted && e.admitted, "no ladder, nothing shed");
        assert_eq!(f.tokens, e.tokens, "req {}: streams policy-invariant", f.id);
    }
}

#[test]
fn edf_strictly_beats_fifo_goodput_under_overload() {
    // The property the TARDIS_ASSERT_GOODPUT CI lane enforces, asserted
    // here so a plain `cargo test` catches regressions too.
    let cfg = ci_config();
    let fifo = run(PolicyKind::Fifo, true, &cfg);
    let edf = run(PolicyKind::Edf, true, &cfg);
    assert!(
        edf.goodput() > fifo.goodput(),
        "edf goodput {:.3} must strictly exceed fifo {:.3} on the overload fixture",
        edf.goodput(),
        fifo.goodput()
    );
    // The fixture is built to overload the lane: the ladder must have
    // real work to do, and deadline scheduling must matter.
    assert!(fifo.goodput() < 1.0, "fifo must miss deadlines under overload");
    for r in [&fifo, &edf] {
        assert!(r.degraded() > 0, "ladder must degrade some bulk requests");
        assert!(r.shed() > 0, "ladder must shed some bulk requests");
        for o in &r.outcomes {
            if o.tier > 0 {
                assert!(o.admitted, "interactive tier is never shed");
                assert!(!o.degraded, "interactive tier is never degraded");
            }
        }
    }
}
