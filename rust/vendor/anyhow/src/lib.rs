//! Offline stand-in for the `anyhow` crate (the vendor set has no network
//! access, and the real crate is not baked into the image).
//!
//! Implements exactly the surface this repository uses: [`Error`] (a
//! message-carrying error), [`Result`], the [`anyhow!`] / [`bail!`] /
//! [`ensure!`] macros, and the [`Context`] extension trait for
//! `Result<T, E: std::error::Error>`. Cause chains are flattened into the
//! message at conversion time rather than kept as a linked list — enough
//! for log lines and test assertions, with zero dependencies.

use std::error::Error as StdError;
use std::fmt;

/// A flattened, message-carrying error.
pub struct Error {
    msg: String,
}

pub type Result<T, E = Error> = std::result::Result<T, E>;

impl Error {
    /// Construct from anything printable (what `anyhow!` expands to).
    pub fn msg<M: fmt::Display>(m: M) -> Error {
        Error { msg: m.to_string() }
    }

    /// Prepend context, mirroring `anyhow::Error::context`.
    pub fn context<C: fmt::Display>(self, c: C) -> Error {
        Error { msg: format!("{c}: {}", self.msg) }
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.msg)
    }
}

impl fmt::Debug for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.msg)
    }
}

// Like the real anyhow, `Error` deliberately does NOT implement
// `std::error::Error`, which is what makes this blanket conversion (and
// therefore `?` on io/fmt/... errors) coherent.
impl<E: StdError + Send + Sync + 'static> From<E> for Error {
    fn from(e: E) -> Error {
        let mut msg = e.to_string();
        let mut src = e.source();
        while let Some(s) = src {
            msg.push_str(": ");
            msg.push_str(&s.to_string());
            src = s.source();
        }
        Error { msg }
    }
}

/// Context-prepending extension for `Result`, mirroring `anyhow::Context`.
pub trait Context<T, E> {
    fn context<C: fmt::Display>(self, c: C) -> Result<T, Error>;
    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T, Error>;
}

impl<T, E: StdError + Send + Sync + 'static> Context<T, E> for std::result::Result<T, E> {
    fn context<C: fmt::Display>(self, c: C) -> Result<T, Error> {
        self.map_err(|e| Error::from(e).context(c))
    }

    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T, Error> {
        match self {
            Ok(v) => Ok(v),
            Err(e) => Err(Error::from(e).context(f())),
        }
    }
}

/// Construct an [`Error`] from a format string.
#[macro_export]
macro_rules! anyhow {
    ($($arg:tt)+) => {
        $crate::Error::msg(::std::format!($($arg)+))
    };
}

/// Return early with an [`Error`] built from a format string.
#[macro_export]
macro_rules! bail {
    ($($arg:tt)+) => {
        return ::std::result::Result::Err($crate::anyhow!($($arg)+))
    };
}

/// Return early with an [`Error`] unless the condition holds.
#[macro_export]
macro_rules! ensure {
    ($cond:expr $(,)?) => {
        if !($cond) {
            $crate::bail!("condition failed: {}", ::std::stringify!($cond));
        }
    };
    ($cond:expr, $($arg:tt)+) => {
        if !($cond) {
            $crate::bail!($($arg)+);
        }
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    fn io_fail() -> Result<()> {
        std::fs::read("/definitely/not/a/path/anywhere")?;
        Ok(())
    }

    #[test]
    fn question_mark_converts_std_errors() {
        let e = io_fail().unwrap_err();
        assert!(!e.to_string().is_empty());
    }

    #[test]
    fn macros_build_messages() {
        let e = anyhow!("slot {} busy", 3);
        assert_eq!(e.to_string(), "slot 3 busy");

        fn f(n: usize) -> Result<usize> {
            ensure!(n < 10, "n too big: {n}");
            if n == 5 {
                bail!("five is right out");
            }
            Ok(n)
        }
        assert_eq!(f(3).unwrap(), 3);
        assert_eq!(f(5).unwrap_err().to_string(), "five is right out");
        assert_eq!(f(11).unwrap_err().to_string(), "n too big: 11");
    }

    #[test]
    fn bare_ensure_names_the_condition() {
        fn f() -> Result<()> {
            ensure!(1 > 2);
            Ok(())
        }
        assert!(f().unwrap_err().to_string().contains("1 > 2"));
    }

    #[test]
    fn context_prepends() {
        let e = io_fail()
            .map_err(|e| e.context("loading manifest"))
            .unwrap_err();
        assert!(e.to_string().starts_with("loading manifest: "));
        let e = std::fs::read("/nope/nope")
            .with_context(|| format!("reading {}", "/nope/nope"))
            .unwrap_err();
        assert!(e.to_string().starts_with("reading /nope/nope: "));
    }
}
