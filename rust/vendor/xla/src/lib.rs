//! API-compatible stub for the `xla` (xla-rs) PJRT bindings.
//!
//! The container image does not ship the real XLA/PJRT shared libraries,
//! so this crate provides the exact type/method surface the `tardis`
//! runtime layer compiles against (`cargo build --features pjrt`
//! type-checks end to end) while every entry point that would touch a
//! device fails fast with a descriptive error. To run on real hardware,
//! point the `xla` path dependency in `rust/Cargo.toml` at the actual
//! xla-rs checkout — no source change in `tardis` is needed.

use std::fmt;

const STUB_MSG: &str =
    "xla stub: built without real PJRT bindings (swap rust/vendor/xla for xla-rs)";

/// Error type mirroring xla-rs's: printable, nothing more is relied on.
#[derive(Debug, Clone)]
pub struct Error(pub String);

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.0)
    }
}

impl std::error::Error for Error {}

pub type Result<T> = std::result::Result<T, Error>;

fn stub_err<T>() -> Result<T> {
    Err(Error(STUB_MSG.to_string()))
}

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ElementType {
    F32,
    F16,
    S32,
    S8,
    U8,
    Pred,
}

pub struct PjRtDevice {
    _private: (),
}

pub struct PjRtBuffer {
    _private: (),
}

impl PjRtBuffer {
    pub fn to_literal_sync(&self) -> Result<Literal> {
        stub_err()
    }
}

pub struct Literal {
    _private: (),
}

impl Literal {
    pub fn create_from_shape_and_untyped_data(
        _ty: ElementType,
        _dims: &[usize],
        _data: &[u8],
    ) -> Result<Literal> {
        stub_err()
    }

    pub fn to_vec<T>(&self) -> Result<Vec<T>> {
        stub_err()
    }
}

pub struct HloModuleProto {
    _private: (),
}

impl HloModuleProto {
    pub fn from_text_file(_path: &str) -> Result<HloModuleProto> {
        stub_err()
    }
}

pub struct XlaComputation {
    _private: (),
}

impl XlaComputation {
    pub fn from_proto(_proto: &HloModuleProto) -> XlaComputation {
        XlaComputation { _private: () }
    }
}

pub struct PjRtLoadedExecutable {
    _private: (),
}

impl PjRtLoadedExecutable {
    pub fn execute_b(&self, _args: &[&PjRtBuffer]) -> Result<Vec<Vec<PjRtBuffer>>> {
        stub_err()
    }
}

pub struct PjRtClient {
    _private: (),
}

impl PjRtClient {
    pub fn cpu() -> Result<PjRtClient> {
        stub_err()
    }

    pub fn platform_name(&self) -> String {
        "stub".to_string()
    }

    pub fn compile(&self, _comp: &XlaComputation) -> Result<PjRtLoadedExecutable> {
        stub_err()
    }

    pub fn buffer_from_host_buffer<T: Copy>(
        &self,
        _data: &[T],
        _dims: &[usize],
        _device: Option<&PjRtDevice>,
    ) -> Result<PjRtBuffer> {
        stub_err()
    }

    pub fn buffer_from_host_literal(
        &self,
        _device: Option<&PjRtDevice>,
        _lit: &Literal,
    ) -> Result<PjRtBuffer> {
        stub_err()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn every_entry_point_fails_with_the_stub_message() {
        assert!(PjRtClient::cpu().unwrap_err().to_string().contains("xla stub"));
        assert!(HloModuleProto::from_text_file("x").is_err());
        assert!(Literal::create_from_shape_and_untyped_data(
            ElementType::F32,
            &[2, 2],
            &[0u8; 16]
        )
        .is_err());
    }
}
